//! `llep` — CLI for the LLEP reproduction.
//!
//! Subcommands:
//!   figures    regenerate paper figures/tables (--fig 1a|1b|1c|3|4|5|6a|6b|7a|7b|8|9|all)
//!   run        run an experiment config (--config file.toml)
//!   calibrate  fit the GEMM cost model to this machine
//!   trace      generate + save a synthetic routing trace (--out t.json)
//!   replay     replay a saved trace under EP/LLEP/EPLB (--trace t.json)
//!   train      Fig.-5 training run from AOT artifacts (--steps N)
//!   serve      serving simulation (EP vs LLEP, or --planner <spec>)
//!   tune       search planner-spec space for a hardware profile and
//!              emit a latency/memory Pareto front (--profile, --budget)
//!   chaos      fault & heterogeneity injection: serve under a FaultPlan
//!              (--faults) and compare static EP vs chaos-aware LLEP
//!   fleet      multi-replica cluster simulation: N replicas behind a
//!              router (--replicas, --router, --workload, --speeds,
//!              --deadline), with whole-replica fail/recover chaos
//!   bench      run a pinned micro-benchmark suite (--suite hotpath) and
//!              write (--out) or gate against (--check) a JSON baseline
//!   info       print presets, the planner registry and environment
//!
//! Fault plans (`--faults`, accepted by run/serve/tune/chaos) are spec
//! strings like `slow:dev=0,x=4;fail:dev=3,at=16` (kinds: slow, stall,
//! fail, recover, link, jitter) or paths to a TOML file with
//! `faults = "..."` under `[chaos]`. The `fleet` subcommand instead
//! reads `--faults` in the whole-replica grammar
//! (`fail:r=1,at=0.02;recover:r=1,at=0.05`, times in virtual seconds).
//! `--planner @report.json` reads the recommended spec from a
//! `tune --out` report, so a pinned recommendation is directly
//! consumable by run/serve/fleet.
//!
//! Planner selection is open; the examples below are canonical registry
//! specs (they round-trip through `planner/registry.rs` unchanged):
//! `--planner llep:alpha=1,m=64,lambda=1.3`, `--planner lpt:min=1024`,
//! `--planner cached(ep):drift=0.05,every=0,q=1024` — run `llep info`
//! for the full registered list. `--plan-reuse`, `--replan-every N` and
//! `--cache-drift F` wrap the selected planners in the cross-step plan
//! cache (decode-regime optimization).
//!
//! Reproducibility: every subcommand that draws random workloads
//! (`run`, `trace`, `serve`, `tune`) derives all scenario/trace RNG from
//! `--seed` (default 0), so identical invocations produce identical
//! tables; `replay` is deterministic given its trace file.

use llep::chaos::FaultPlan;
use llep::config::{
    load_experiment, LlepConfig, ModelConfig, ModelPreset, SystemConfig, SystemPreset,
};
use llep::coordinator::{RunSummary, Runner, ServeReport, ServeSim};
use llep::exec::{Engine, PlanCostModel};
use llep::harness;
use llep::fleet::{
    FleetFaultPlan, FleetSim, OverloadConfig, ReplicaConfig, RouterPolicy, Workload,
};
use llep::metrics::{
    chaos_stats_to_json, fleet_replica_table, fleet_report_to_json, format_bytes, format_cache,
    format_chaos, format_placement, format_secs, model_report_table, placement_to_json,
    tune_front_table, tune_report_to_json, tune_trials_table, Table, SCHEMA_VERSION,
};
use llep::planner::{CachedPlanner, Planner, PlannerKind, Registry};
use llep::routing::{DepthProfile, RoutingTrace, Scenario};
use llep::trace::{name_engine_tracks, Tracer};
use llep::tune::{HardwareProfile, Mode, SearchSpace, SpaceBudget, Strategy, Tuner};
use llep::util::cli::Spec;
use llep::util::json::Json;
use llep::util::rng::Rng;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let spec = Spec::new()
        .opt("fig", "figure id (1a 1b 1c 3 4 5 6a 6b 7a 7b 8 9 all)")
        .opt("config", "experiment TOML file")
        .opt("out", "output path")
        .opt(
            "trace",
            "replay: routing-trace input; run/serve/chaos/fleet: write a Chrome trace \
             timeline (Perfetto) to this path",
        )
        .opt("steps", "training steps / serve requests")
        .opt("batches", "trace batches")
        .opt("devices", "EP world size")
        .opt("tokens", "tokens per device")
        .opt("layers", "MoE layer count override for full-model pricing")
        .opt("alpha", "LLEP capacity factor")
        .opt("lambda", "LLEP imbalance trigger")
        .opt("min-gemm", "LLEP min tokens per GEMM")
        .opt("model", "model preset name")
        .opt("system", "system preset name, e.g. h200x8 | mixed-h100-a100 (default h200x8)")
        .opt("scenario", "balanced | concentrated | powerlaw | drift")
        .opt("concentration", "fraction of tokens into hot experts")
        .opt("hot", "number of hot experts")
        .opt("seed", "rng seed for all scenario/trace randomness (default 0)")
        .opt("profile", "tune: hardware profile name or TOML path (default h200x8)")
        .opt("budget", "tune: search-space budget, smoke | default | full")
        .opt("strategy", "tune: grid | random | halving (default grid)")
        .opt("mode", "tune: step | serve objective (default step)")
        .opt("trials", "tune: candidate count for --strategy random")
        .opt("artifacts", "artifacts directory (default ./artifacts)")
        .opt("faults", "fault plan: spec like slow:dev=0,x=4;fail:dev=3,at=16, or a TOML path")
        .opt("pin", "tune: pin file — bootstrap when missing, fail when the optimum moved")
        .opt("planner", "planner spec (see `llep info`), or @report.json from `tune --out`")
        .opt("replan-every", "plan cache: force a fresh plan every N reuses (0 = never)")
        .opt("cache-drift", "plan cache: load-signature drift threshold (default 0.05)")
        .opt("replicas", "fleet: number of serving replicas (default 2)")
        .opt("router", "fleet: round-robin | least-queue | pressure (default least-queue)")
        .opt("workload", "fleet: workload spec, e.g. bursty:n=64,ia=0.0002,burst=8,every=16")
        .opt("speeds", "fleet: per-replica speed multipliers, e.g. 1.0,0.5")
        .opt("deadline", "fleet: SLO deadline in seconds for goodput (0 = none)")
        .opt("queue-cap", "fleet: per-replica queue cap; overflow spills or buffers (0 = none)")
        .opt("frontend-cap", "fleet: bounded frontend buffer when all replicas refuse (default 64)")
        .opt("retries", "fleet: max retries per failed request before shedding (default 3)")
        .opt("backoff", "fleet: retry backoff base seconds (default 0.001)")
        .opt("backoff-cap", "fleet: retry backoff ceiling seconds (default 0.016)")
        .opt("breaker-after", "fleet: consecutive failures that open a breaker (default 1)")
        .opt("breaker-cooldown", "fleet: breaker open time before the half-open probe (default 0.005)")
        .opt("suite", "bench: suite name (hotpath)")
        .opt("check", "bench: pin JSON — bootstrap when missing, fail on median regression")
        .opt("tolerance", "bench: allowed median regression vs the pin (default 0.25)")
        .flag(
            "admission",
            "fleet: deadline admission control — shed requests no replica can finish in time \
             (requires --deadline)",
        )
        .flag("quick", "bench: CI-sized measurement budgets")
        .flag("plan-reuse", "wrap planners in the cross-step plan cache")
        .flag("full-model", "price every MoE layer per step (pipelined planning)")
        .flag("real", "measure real GEMMs where applicable")
        .flag("help", "show usage");

    let args = match spec.parse(&argv, true) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\nOptions:\n{}", spec.help());
            std::process::exit(2);
        }
    };
    if args.has_flag("help") || args.subcommand.is_none() {
        println!("llep — Least-Loaded Expert Parallelism (paper reproduction)\n");
        println!(
            "usage: llep <figures|run|calibrate|trace|replay|train|serve|tune|chaos|fleet|bench|\
             info> [options]\n"
        );
        println!("Options:\n{}", spec.help());
        return;
    }

    let result = match args.subcommand.as_deref().unwrap() {
        "figures" => cmd_figures(&args),
        "run" => cmd_run(&args),
        "calibrate" => cmd_calibrate(),
        "trace" => cmd_trace(&args),
        "replay" => cmd_replay(&args),
        "train" => cmd_train(&args),
        "serve" => cmd_serve(&args),
        "tune" => cmd_tune(&args),
        "chaos" => cmd_chaos(&args),
        "fleet" => cmd_fleet(&args),
        "bench" => cmd_bench(&args),
        "info" => cmd_info(),
        other => Err(format!("unknown subcommand {other:?} (see --help)")),
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn print_table(title: &str, t: &Table) {
    println!("\n== {title} ==");
    println!("{}", t.render());
}

fn cmd_figures(args: &llep::util::cli::Args) -> Result<(), String> {
    let fig = args.get_or("fig", "all");
    let real = args.has_flag("real");
    let all = fig == "all";
    if all || fig == "1a" {
        print_table("Fig 1a — MoE layer speedup, 128E/top4/D2048, P=8", &harness::fig_1a());
        println!("{}", harness::fig_1a_chart().render());
    }
    if all || fig == "1b" {
        print_table("Fig 1b — peak memory per GPU", &harness::fig_1b());
    }
    if all || fig == "1c" {
        print_table("Fig 1c — full-model throughput (in-the-wild routing)", &harness::fig_1c());
    }
    if all || fig == "3" {
        let (a, b) = harness::fig_3();
        print_table("Fig 3a — per-expert max load share", &a);
        print_table("Fig 3b — per-GPU max load share", &b);
    }
    if all || fig == "4" {
        print_table(
            "Fig 4 — three architectures (gpt-oss-120b / DSv3 / Kimi-K2)",
            &harness::fig_4(),
        );
    }
    if all || fig == "5" {
        match fig5_curve() {
            Ok(()) => {}
            Err(e) => println!(
                "\n== Fig 5 — loss vs wall-clock ==\nskipped: {e}\n(run `make artifacts`, \
                 or use `cargo run --release --example e2e_train`)"
            ),
        }
    }
    if all || fig == "6a" {
        print_table("Fig 6a — speedup vs batch size (4 hot experts)", &harness::fig_6a());
    }
    if all || fig == "6b" {
        print_table("Fig 6b — speedup vs alpha", &harness::fig_6b());
    }
    if all || fig == "7a" {
        print_table("Fig 7a — speedup vs lambda (B=8K)", &harness::fig_7a());
    }
    if all || fig == "7b" {
        print_table("Fig 7b — speedup vs hidden size", &harness::fig_7b());
    }
    if all || fig == "8" {
        print_table(
            "Fig 8 — grouped-GEMM: time vs #experts at fixed FLOPs",
            &harness::fig_8(real || all),
        );
    }
    if all || fig == "9" {
        print_table("Fig 9 — speedup vs number of experts", &harness::fig_9());
    }
    Ok(())
}

/// Short Fig-5 run (60 steps) for `figures --fig 5`; the full experiment
/// lives in examples/e2e_train.rs.
#[cfg(not(feature = "pjrt"))]
fn fig5_curve() -> Result<(), String> {
    Err("built without the `pjrt` feature (PJRT/XLA runtime unavailable)".into())
}

#[cfg(feature = "pjrt")]
fn fig5_curve() -> Result<(), String> {
    let rt = llep::runtime::Runtime::open(&llep::runtime::Runtime::default_dir())
        .map_err(|e| format!("{e:#}"))?;
    let mut trainer = llep::trainer::Trainer::new(&rt, 0.0).map_err(|e| format!("{e:#}"))?;
    let engine = Engine::modeled(
        ModelConfig::preset(ModelPreset::Tiny),
        SystemConfig::preset(SystemPreset::CpuSim4),
    );
    let mut rng = Rng::new(42);
    let curve = trainer
        .run_curve(60, &engine, &mut rng, |_| {})
        .map_err(|e| format!("{e:#}"))?;
    let last = curve.last().unwrap();
    println!("\n== Fig 5 — loss vs wall-clock (60 steps; see examples/e2e_train for 300) ==");
    let mut plot = llep::metrics::chart::SeriesPlot::new(
        "loss vs wall-clock seconds  (E = standard EP, L = LLEP)",
    );
    plot.series('E', curve.iter().map(|p| (p.wall_ep_s, p.loss as f64)).collect());
    plot.series('L', curve.iter().map(|p| (p.wall_llep_s, p.loss as f64)).collect());
    println!("{}", plot.render());
    println!(
        "loss {:.3} -> {:.3}; MoE wall-clock EP {} vs LLEP {} ({:.2}x)",
        curve[0].loss,
        last.loss,
        format_secs(last.wall_ep_s),
        format_secs(last.wall_llep_s),
        last.wall_ep_s / last.wall_llep_s
    );
    Ok(())
}

fn scenario_from_args(args: &llep::util::cli::Args) -> Result<Scenario, String> {
    let conc = args.get_f64("concentration", 0.8)?;
    let hot = args.get_usize("hot", 4)?;
    Ok(match args.get_or("scenario", "concentrated").as_str() {
        "balanced" => Scenario::balanced(),
        "concentrated" => Scenario::concentrated(conc, hot),
        "powerlaw" => Scenario::power_law(1.2),
        "drift" => Scenario::drifting(hot, conc.min(0.95), 0.25),
        other => return Err(format!("unknown scenario {other}")),
    })
}

/// Resolve one `--planner` argument: a registry spec string, or
/// `@path.json` naming a `tune --out` report whose recommended spec is
/// used directly (the pinned-recommendation consumption path).
fn resolve_planner_arg(spec: &str) -> Result<Box<dyn Planner>, String> {
    if let Some(path) = spec.strip_prefix('@') {
        let text =
            std::fs::read_to_string(path).map_err(|e| format!("--planner {spec}: {e}"))?;
        let report = llep::util::json::parse(&text)
            .map_err(|e| format!("--planner {spec}: not a JSON tune report: {e}"))?;
        let rec = report
            .get("recommended")
            .and_then(|r| r.get("spec"))
            .and_then(|s| s.as_str())
            .ok_or_else(|| {
                format!(
                    "--planner {spec}: no recommended.spec field (expected a report written \
                     by `llep tune --out`)"
                )
            })?;
        println!("planner from {path}: {rec}");
        return Registry::builtin().parse(rec);
    }
    Registry::builtin().parse(spec)
}

/// Planner selection: `--planner <spec>` overrides `defaults`, then
/// `--plan-reuse` / `--replan-every` / `--cache-drift` optionally wrap
/// every planner in the cross-step plan cache.
fn planners_from_args(
    args: &llep::util::cli::Args,
    defaults: Vec<Box<dyn Planner>>,
) -> Result<Vec<Box<dyn Planner>>, String> {
    let base = match args.get("planner") {
        Some(spec) => vec![resolve_planner_arg(spec)?],
        None => defaults,
    };
    let reuse = args.has_flag("plan-reuse")
        || args.get("replan-every").is_some()
        || args.get("cache-drift").is_some();
    if !reuse {
        return Ok(base);
    }
    let drift = args.get_f64("cache-drift", 0.05)?;
    let every = args.get_usize("replan-every", 0)?;
    let mut wrapped: Vec<Box<dyn Planner>> = Vec::with_capacity(base.len());
    for p in base {
        if p.spec().contains("cached(") {
            // A cache is already configured somewhere inside this spec:
            // wrapping it again would shadow the user's configured cache,
            // and quietly ignoring the flags would run a different
            // experiment than the command line states — refuse instead.
            // (Stateful-but-uncached specs like placed(llep) are fine to
            // wrap: the outer cache keys entries to the layout generation.)
            return Err(format!(
                "--plan-reuse/--replan-every/--cache-drift cannot be combined with the \
                 already-cached planner spec {:?}; set drift=/every=/q= inside the spec",
                p.spec()
            ));
        }
        wrapped.push(Box::new(
            CachedPlanner::new(p).with_drift_threshold(drift).with_replan_every(every),
        ));
    }
    Ok(wrapped)
}

/// An enabled [`Tracer`] when the simulation subcommands got
/// `--trace <out.json>`, else the zero-overhead disabled handle.
/// (`replay` reads `--trace` itself as its routing-trace input and never
/// calls this.)
fn tracer_from_args(args: &llep::util::cli::Args) -> Tracer {
    if args.get("trace").is_some() {
        Tracer::enabled()
    } else {
        Tracer::disabled()
    }
}

/// Per-planner engine handles for a traced comparison run: each planner
/// records under its own Chrome pid, so the EP and LLEP timelines of the
/// same workload render side by side in Perfetto. Only called with an
/// enabled tracer (the untraced path keeps the one shared engine).
fn traced_engines(
    engine: &Engine,
    planners: &[Box<dyn Planner>],
    tracer: &Tracer,
) -> Vec<Engine> {
    planners
        .iter()
        .enumerate()
        .map(|(i, p)| {
            let t = tracer.with_pid(i as u32);
            name_engine_tracks(&t, &p.label(), engine.system.devices);
            engine.clone().with_tracer(t)
        })
        .collect()
}

/// Write the recorded timeline to the `--trace` path, if one was given.
/// An unwritable path is a command failure (non-zero exit).
fn write_trace(tracer: &Tracer, args: &llep::util::cli::Args) -> Result<(), String> {
    if let Some(path) = args.get("trace") {
        tracer.write(path)?;
        println!("wrote trace {path} ({} events)", tracer.event_count());
    }
    Ok(())
}

fn engine_from_args(args: &llep::util::cli::Args) -> Result<(Engine, LlepConfig), String> {
    let model_name = args.get_or("model", "fig1-layer");
    let preset = ModelPreset::from_name(&model_name)
        .ok_or_else(|| format!("unknown model preset {model_name}"))?;
    let mut model = ModelConfig::preset(preset);
    let layers = args.get_usize("layers", 0)?;
    if layers > 0 {
        model.num_layers = layers;
    }
    let system_name = args.get_or("system", "h200x8");
    let system_preset = SystemPreset::from_name(&system_name)
        .ok_or_else(|| format!("unknown system preset {system_name} (see `llep info`)"))?;
    let mut system = SystemConfig::preset(system_preset);
    // --devices overrides the preset's pool size; 0/absent keeps it.
    let devices = args.get_usize("devices", 0)?;
    if devices > 0 {
        system = system.with_devices(devices);
    }
    let llep = LlepConfig {
        alpha: args.get_f64("alpha", 1.0)?,
        lambda: args.get_f64("lambda", 1.3)?,
        min_gemm_tokens: args.get_usize("min-gemm", 1024)?,
    };
    llep.validate()?;
    Ok((Engine::modeled(model, system), llep))
}

fn cmd_run(args: &llep::util::cli::Args) -> Result<(), String> {
    let (engine, llep, scenario, tokens, seed) = if let Some(path) = args.get("config") {
        let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
        let cfg = load_experiment(&text)?;
        (
            Engine::modeled(cfg.model, cfg.system),
            cfg.llep,
            cfg.scenario,
            cfg.tokens_per_device,
            cfg.seed,
        )
    } else {
        let (engine, llep) = engine_from_args(args)?;
        let scenario = scenario_from_args(args)?;
        let tokens = args.get_usize("tokens", 32_768)?;
        let seed = args.get_usize("seed", 0)? as u64;
        (engine, llep, scenario, tokens, seed)
    };

    // `run --faults`: a single step prices under the plan's step-0 pool
    // view (step-indexed schedules belong to serve/chaos).
    let engine = match args.get("faults") {
        Some(arg) => {
            let plan = FaultPlan::resolve(arg)?;
            plan.validate(engine.system.devices)?;
            let pool = plan.state_at(0, &engine.pool);
            engine.with_pool(pool)
        }
        None => engine,
    };

    let defaults: Vec<Box<dyn Planner>> = vec![
        PlannerKind::StandardEp.boxed(),
        PlannerKind::Llep(llep).boxed(),
        PlannerKind::Eplb { replicas: engine.system.devices }.boxed(),
    ];
    let planners = planners_from_args(args, defaults)?;
    let tracer = tracer_from_args(args);

    if args.has_flag("full-model") {
        cmd_run_full_model(&engine, &planners, &scenario, tokens, seed, &tracer)?;
        return write_trace(&tracer, args);
    }

    let mut rng = Rng::new(seed);
    let lm = scenario.generate_loads(&engine.model, engine.system.devices, tokens, &mut rng);
    let traced =
        if tracer.is_enabled() { traced_engines(&engine, &planners, &tracer) } else { Vec::new() };
    let mut t = Table::new(&[
        "planner", "latency", "compute max", "dispatch", "weights", "peak mem", "xfers", "status",
    ]);
    for (i, planner) in planners.iter().enumerate() {
        let r = traced.get(i).unwrap_or(&engine).run_step_loads(&lm, &**planner);
        let status = if r.oom {
            "OOM"
        } else if r.stranded {
            "STRANDED"
        } else {
            "-"
        };
        t.row(vec![
            r.planner.clone(),
            format_secs(r.latency_s),
            format_secs(r.phases.compute_s),
            format_secs(r.phases.dispatch_s),
            format_secs(r.phases.weights_s),
            format_bytes(r.max_peak_bytes()),
            r.weight_transfers.to_string(),
            status.into(),
        ]);
    }
    let pool_note = if engine.pool.is_degraded() {
        format!(" | pool: {}", engine.pool.label())
    } else {
        String::new()
    };
    print_table(
        &format!(
            "{} | P={} | {} tokens/device | {}{pool_note}",
            engine.model.name,
            engine.system.devices,
            tokens,
            scenario.label()
        ),
        &t,
    );
    write_trace(&tracer, args)
}

/// `run --full-model`: price one forward step across every MoE layer of
/// the model with per-layer plans and pipelined planning, then show the
/// per-layer LLEP breakdown. Drifting scenarios expand to a depth-varying
/// profile (a different hotspot per layer); others apply uniformly.
fn cmd_run_full_model(
    engine: &Engine,
    planners: &[Box<dyn Planner>],
    scenario: &Scenario,
    tokens: usize,
    seed: u64,
    tracer: &Tracer,
) -> Result<(), String> {
    let layers = engine.model.num_moe_layers();
    let profile = match scenario {
        Scenario::Drifting { dominance, drift, .. } => {
            DepthProfile::varying(&engine.model, *dominance, *drift)
        }
        _ => DepthProfile::uniform(scenario.clone(), layers),
    };
    let mut rng = Rng::new(seed);
    let lms = profile.generate_loads(&engine.model, engine.system.devices, tokens, &mut rng);

    let mut t = Table::new(&[
        "planner", "latency", "serial", "overlap saved", "peak mem", "xfers", "fallback",
        "plan cache", "OOM",
    ]);
    let traced =
        if tracer.is_enabled() { traced_engines(engine, planners, tracer) } else { Vec::new() };
    let mut reports = Vec::with_capacity(planners.len());
    for (i, planner) in planners.iter().enumerate() {
        let r = traced.get(i).unwrap_or(engine).run_model(&lms, &**planner)?;
        t.row(vec![
            r.planner.clone(),
            format_secs(r.latency_s),
            format_secs(r.serial_latency_s),
            format_secs(r.overlap_saved_s),
            format_bytes(r.max_peak_bytes()),
            r.layers.iter().map(|l| l.report.weight_transfers).sum::<usize>().to_string(),
            format!("{}/{}", r.fallback_layers, r.num_layers()),
            format_cache(&r.cache),
            if r.oom { "OOM".into() } else { "-".into() },
        ]);
        reports.push(r);
    }
    print_table(
        &format!(
            "{} | full model, {layers} MoE layers | P={} | {} tokens/device | {}",
            engine.model.name,
            engine.system.devices,
            tokens,
            profile.label()
        ),
        &t,
    );
    // Per-layer breakdown: the single selected planner with `--planner`,
    // else the LLEP slot of the default EP/LLEP/EPLB comparison — chosen
    // by position, not by sniffing display labels.
    let breakdown = if reports.len() == 1 { reports.first() } else { reports.get(1) };
    if let Some(r) = breakdown {
        print_table(
            &format!("{} per-layer breakdown", r.planner),
            &model_report_table(r),
        );
    }
    Ok(())
}

fn cmd_calibrate() -> Result<(), String> {
    use llep::costmodel::calibrate;
    println!("measuring native GEMM (D=H=256)...");
    let sweep = [8u64, 16, 32, 64, 128, 256, 512, 1024, 2048];
    let samples = calibrate::measure_native(256, 256, &sweep, 3);
    for s in &samples {
        println!("  B={:<6} {}", s.tokens, format_secs(s.seconds));
    }
    let fitted = calibrate::fit(&samples, 48.0);
    let rms = calibrate::rms_rel_error(&fitted, &samples);
    println!("\nfitted GEMM cost model (rms rel err {:.1}%):", rms * 100.0);
    println!("  overhead_s      = {:.3e}", fitted.overhead_s);
    println!("  peak_flops      = {:.3e}", fitted.peak_flops);
    println!("  tokens_half_eff = {:.1}", fitted.tokens_half_eff);
    println!("\npaste into SystemConfig::CpuSim8 to recalibrate the simulator.");
    Ok(())
}

fn cmd_trace(args: &llep::util::cli::Args) -> Result<(), String> {
    let (engine, _) = engine_from_args(args)?;
    let scenario = scenario_from_args(args)?;
    let batches = args.get_usize("batches", 32)?;
    let tokens = args.get_usize("tokens", 8192)?;
    let seed = args.get_usize("seed", 0)? as u64;
    let out = args.get_or("out", "trace.json");
    let mut rng = Rng::new(seed);
    let mut trace =
        RoutingTrace::new(&scenario.label(), engine.model.num_experts, engine.model.top_k);
    for _ in 0..batches {
        trace
            .push(scenario.generate_loads(&engine.model, engine.system.devices, tokens, &mut rng))?;
    }
    trace.save(std::path::Path::new(&out)).map_err(|e| e.to_string())?;
    println!("wrote {batches} batches to {out}");
    Ok(())
}

fn cmd_replay(args: &llep::util::cli::Args) -> Result<(), String> {
    let path = args.get("trace").ok_or("--trace required")?;
    let trace = RoutingTrace::load(std::path::Path::new(path))?;
    let (engine, llep) = engine_from_args(args)?;
    if trace.num_experts != engine.model.num_experts {
        return Err(format!(
            "trace has {} experts; pass --model with a matching preset",
            trace.num_experts
        ));
    }
    let defaults: Vec<Box<dyn Planner>> = vec![
        PlannerKind::StandardEp.boxed(),
        PlannerKind::Llep(llep).boxed(),
        PlannerKind::Eplb { replicas: engine.system.devices }.boxed(),
    ];
    let mut t =
        Table::new(&["planner", "total time", "p50 step", "p99 step", "peak mem", "OOM batches"]);
    for planner in planners_from_args(args, defaults)? {
        let mut runner = Runner::with_planner(engine.clone(), planner);
        let reports = runner.run_trace(&trace);
        let s = RunSummary::of(&reports);
        t.row(vec![
            s.planner.clone(),
            format_secs(s.total_latency_s),
            format_secs(s.latency.p50),
            format_secs(s.latency.p99),
            format_bytes(s.peak_bytes),
            s.oom_batches.to_string(),
        ]);
    }
    print_table(&format!("replay {path} ({} batches)", trace.batches.len()), &t);
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_train(_args: &llep::util::cli::Args) -> Result<(), String> {
    Err("`train` needs the PJRT runtime — rebuild with `--features pjrt` \
         (requires the vendored xla/anyhow crates)"
        .into())
}

#[cfg(feature = "pjrt")]
fn cmd_train(args: &llep::util::cli::Args) -> Result<(), String> {
    let steps = args.get_usize("steps", 200)?;
    let dir = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(llep::runtime::Runtime::default_dir);
    let rt = llep::runtime::Runtime::open(&dir).map_err(|e| format!("{e:#}"))?;
    let mut trainer = llep::trainer::Trainer::new(&rt, 0.0).map_err(|e| format!("{e:#}"))?;
    let engine = Engine::modeled(
        ModelConfig::preset(ModelPreset::Tiny),
        SystemConfig::preset(SystemPreset::CpuSim4),
    );
    let mut rng = Rng::new(args.get_usize("seed", 0)? as u64);
    println!("step  loss      wall(EP)    wall(LLEP)  measured/step");
    let curve = trainer
        .run_curve(steps, &engine, &mut rng, |p| {
            if p.step % 10 == 0 || p.step + 1 == steps {
                println!(
                    "{:<5} {:<9.4} {:<11} {:<11} {}",
                    p.step,
                    p.loss,
                    format_secs(p.wall_ep_s),
                    format_secs(p.wall_llep_s),
                    format_secs(p.measured_step_s)
                );
            }
        })
        .map_err(|e| format!("{e:#}"))?;
    let last = curve.last().unwrap();
    println!(
        "\nfinal loss {:.4}; virtual wall-clock speedup (MoE layers): {:.2}x",
        last.loss,
        last.wall_ep_s / last.wall_llep_s
    );
    Ok(())
}

fn cmd_serve(args: &llep::util::cli::Args) -> Result<(), String> {
    let (engine, llep) = engine_from_args(args)?;
    let scenario = scenario_from_args(args)?;
    let n = args.get_usize("steps", 64)?;
    let seed = args.get_usize("seed", 0)? as u64;
    let faults = match args.get("faults") {
        Some(arg) => {
            let plan = FaultPlan::resolve(arg)?;
            plan.validate(engine.system.devices)?;
            Some(plan)
        }
        None => None,
    };
    let mut rng = Rng::new(seed);
    let requests = ServeSim::poisson_requests(n, 0.0005, 256, 2048, &mut rng);
    let defaults: Vec<Box<dyn Planner>> =
        vec![PlannerKind::StandardEp.boxed(), PlannerKind::Llep(llep).boxed()];
    let mut t = Table::new(&[
        "planner", "makespan", "p50 latency", "p99 latency", "tok/s", "p50 plan", "plan cache",
        "placement", "chaos",
    ]);
    let tracer = tracer_from_args(args);
    let mut unrecoverable: Vec<(String, String)> = Vec::new();
    for (i, planner) in planners_from_args(args, defaults)?.into_iter().enumerate() {
        let label = planner.label();
        let mut sim_engine = engine.clone();
        if tracer.is_enabled() {
            let t = tracer.with_pid(i as u32);
            name_engine_tracks(&t, &label, engine.system.devices);
            sim_engine = sim_engine.with_tracer(t);
        }
        let mut sim = ServeSim::with_planner(sim_engine, planner, scenario.clone(), 8192);
        if let Some(f) = &faults {
            sim = sim.with_faults(f.clone());
        }
        match sim.try_run(&requests, &mut Rng::new(seed + 1)) {
            Ok(r) => {
                assert!(r.tokens.is_exact(), "accounting contract: {:?}", r.tokens);
                t.row(vec![
                    r.planner.clone(),
                    format_secs(r.makespan_s),
                    format_secs(r.request_latency.p50),
                    format_secs(r.request_latency.p99),
                    format!("{:.0}", r.throughput_tps()),
                    format_secs(r.plan_time.p50),
                    format_cache(&r.plan_cache),
                    format_placement(&r.placement),
                    format_chaos(&r.chaos),
                ]);
            }
            // A planner that cannot survive the fault plan is a result,
            // not a command failure: keep the table so the adaptive rows
            // still render (mirrors `llep chaos`).
            Err(e) => {
                t.row(vec![
                    label.clone(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "unrecoverable".into(),
                ]);
                unrecoverable.push((label, e));
            }
        }
    }
    let fault_note = faults
        .as_ref()
        .map(|f| format!(" | faults: {}", f.label()))
        .unwrap_or_default();
    print_table(&format!("serving {n} requests | {}{fault_note}", scenario.label()), &t);
    for (label, e) in &unrecoverable {
        println!("{label}: {e}");
    }
    write_trace(&tracer, args)
}

/// `llep tune`: enumerate planner-spec space for one hardware profile +
/// scenario, search it (grid / random / successive halving), print the
/// trial table and latency/memory Pareto front, and verify that the
/// recommended spec re-prices bit-identically (the round-trip contract:
/// the same spec passed back as `--planner` reproduces the trial).
fn cmd_tune(args: &llep::util::cli::Args) -> Result<(), String> {
    let profile = HardwareProfile::resolve(&args.get_or("profile", "h200x8"))?;
    let scenario = scenario_from_args(args)?;
    let model_name = args.get_or("model", "fig1-layer");
    let preset = ModelPreset::from_name(&model_name)
        .ok_or_else(|| format!("unknown model preset {model_name}"))?;
    let mut model = ModelConfig::preset(preset);
    let layers = args.get_usize("layers", 0)?;
    if layers > 0 {
        model.num_layers = layers;
    }
    let mut system = profile.system.clone();
    if args.get("devices").is_some() {
        system = system.with_devices(args.get_usize("devices", system.devices)?);
    }
    let seed = args.get_usize("seed", 0)? as u64;
    let budget_name = args.get_or("budget", "default");
    let budget = SpaceBudget::from_name(&budget_name)
        .ok_or_else(|| format!("unknown budget {budget_name:?} (smoke | default | full)"))?;
    let mode_name = args.get_or("mode", "step");
    let mode = Mode::from_name(&mode_name)
        .ok_or_else(|| format!("unknown mode {mode_name:?} (step | serve)"))?;
    let strategy = match args.get_or("strategy", "grid").as_str() {
        "grid" => Strategy::Grid,
        "random" => Strategy::Random { trials: args.get_usize("trials", 16)? },
        "halving" => Strategy::Halving { eta: 2 },
        other => return Err(format!("unknown strategy {other:?} (grid | random | halving)")),
    };
    let tokens = args.get_usize("tokens", 8192)?;
    let faults = match args.get("faults") {
        Some(arg) => {
            let plan = FaultPlan::resolve(arg)?;
            plan.validate(system.devices)?;
            Some(plan)
        }
        None => None,
    };

    let engine = Engine::modeled(model, system).with_plan_cost(PlanCostModel::default());
    let mut tuner = Tuner::new(engine, scenario.clone(), mode, seed).with_tokens(tokens);
    if let Some(f) = &faults {
        tuner = tuner.with_faults(f.clone());
    }
    if budget == SpaceBudget::Smoke {
        // Halved fidelity keeps the CI smoke sweep fast; other budgets
        // keep the library's full-budget defaults.
        tuner = tuner.with_full_budget(match mode {
            Mode::Step => 4,
            Mode::Serve => 8,
        });
    }
    let space = SearchSpace::from_registry(&tuner.registry, budget)?;
    let outcome = tuner.run(&space, strategy)?;

    let fault_note = faults
        .as_ref()
        .map(|f| format!(" | faults: {}", f.label()))
        .unwrap_or_default();
    let title = format!(
        "tune | profile {} | {} | {} mode | {} | {} specs, {} budget units priced{fault_note}",
        profile.name,
        scenario.label(),
        mode.name(),
        outcome.strategy,
        outcome.specs_considered,
        outcome.priced_units
    );
    let shown: Vec<llep::tune::Trial> = outcome.trials.iter().take(12).cloned().collect();
    print_table(&title, &tune_trials_table(&shown));
    if outcome.trials.len() > shown.len() {
        println!(
            "({} further trials not shown; --out <file> writes the full set as JSON)",
            outcome.trials.len() - shown.len()
        );
    }
    print_table("Pareto front (latency vs peak memory)", &tune_front_table(&outcome));

    // Write the report before the feasibility/verification gates below:
    // an all-OOM sweep is exactly when the full trial set matters.
    if let Some(out) = args.get("out") {
        let json = tune_report_to_json(&outcome, &profile.name, &scenario.label());
        std::fs::write(out, json.to_string_pretty()).map_err(|e| e.to_string())?;
        println!("wrote {out}");
    }

    let recommended = outcome
        .recommended
        .clone()
        .ok_or("tune found no feasible (non-OOM) configuration for this profile")?;
    // Round-trip contract: the spec parses back through the registry and
    // re-prices to the exact reported metrics.
    tuner.registry.parse(&recommended.spec)?;
    let identical = tuner.verify(&recommended)?;
    println!("\nrecommended: --planner {}", recommended.spec);
    println!(
        "re-evaluated bit-identically: {identical} (latency {}, peak {})",
        format_secs(recommended.metrics.latency_s),
        format_bytes(recommended.metrics.peak_bytes)
    );
    if !identical {
        return Err("recommended spec did not re-price bit-identically".into());
    }
    if let Some(pin) = args.get("pin") {
        let context = format!(
            "profile {} | {} | {} mode | {} budget{}",
            profile.name,
            scenario.label(),
            mode.name(),
            budget_name,
            fault_note
        );
        check_or_write_pin(pin, &recommended, &context)?;
    }
    Ok(())
}

/// `tune --pin <file>`: lock a profile's recommended spec. A missing file
/// bootstraps (writes the recommendation); an existing file fails loudly
/// when the recommendation moved. CI sweeps every builtin profile with a
/// checked-in pin, so a planner/cost-model change that silently shifts a
/// hardware profile's optimum turns the build red.
fn check_or_write_pin(
    path: &str,
    recommended: &llep::tune::Trial,
    context: &str,
) -> Result<(), String> {
    match std::fs::read_to_string(path) {
        Ok(text) => {
            let pinned = text
                .lines()
                .map(str::trim)
                .find(|l| !l.is_empty() && !l.starts_with('#'))
                .unwrap_or("");
            if pinned != recommended.spec {
                return Err(format!(
                    "tune pin mismatch: {path} pins {pinned:?} but this sweep recommends {:?} \
                     ({context}) — the optimum moved. If intentional, delete the pin, re-run \
                     `llep tune --pin {path}` and commit the refreshed file.",
                    recommended.spec
                ));
            }
            println!("pin ok: {path} ({pinned})");
            Ok(())
        }
        Err(_) => {
            let body = format!(
                "{}\n# pinned by `llep tune --pin` | {context} | latency {} | peak {}\n",
                recommended.spec,
                format_secs(recommended.metrics.latency_s),
                format_bytes(recommended.metrics.peak_bytes),
            );
            std::fs::write(path, body).map_err(|e| format!("{path}: {e}"))?;
            println!("pin bootstrapped: {path} — commit it to lock this recommendation");
            Ok(())
        }
    }
}

/// `llep chaos`: serve one request burst under a fault/heterogeneity
/// plan and compare planners — static EP either limps (stragglers) or
/// cannot recover at all (failures), while pool-aware LLEP elastically
/// replans. The token ledger stays exact across every requeue.
fn cmd_chaos(args: &llep::util::cli::Args) -> Result<(), String> {
    let (engine, llep) = engine_from_args(args)?;
    let scenario = scenario_from_args(args)?;
    let n = args.get_usize("steps", 48)?;
    let seed = args.get_usize("seed", 0)? as u64;
    let faults = FaultPlan::resolve(&args.get_or("faults", "slow:dev=0,x=4"))?;
    faults.validate(engine.system.devices)?;
    let mut rng = Rng::new(seed);
    let requests = ServeSim::poisson_requests(n, 0.0005, 256, 2048, &mut rng);
    let defaults: Vec<Box<dyn Planner>> =
        vec![PlannerKind::StandardEp.boxed(), PlannerKind::Llep(llep).boxed()];

    let mut t = Table::new(&[
        "planner", "makespan", "p50 latency", "p99 latency", "tok/s", "fault steps", "chaos",
        "status",
    ]);
    let tracer = tracer_from_args(args);
    let mut results: Vec<(String, Result<ServeReport, String>)> = Vec::new();
    for (i, planner) in planners_from_args(args, defaults)?.into_iter().enumerate() {
        let label = planner.label();
        let mut sim_engine = engine.clone();
        if tracer.is_enabled() {
            let t = tracer.with_pid(i as u32);
            name_engine_tracks(&t, &label, engine.system.devices);
            sim_engine = sim_engine.with_tracer(t);
        }
        let sim = ServeSim::with_planner(sim_engine, planner, scenario.clone(), 8192)
            .with_faults(faults.clone());
        let outcome = sim.try_run(&requests, &mut Rng::new(seed + 1));
        match &outcome {
            Ok(r) => {
                assert!(r.tokens.is_exact(), "accounting contract: {:?}", r.tokens);
                t.row(vec![
                    r.planner.clone(),
                    format_secs(r.makespan_s),
                    format_secs(r.request_latency.p50),
                    format_secs(r.request_latency.p99),
                    format!("{:.0}", r.throughput_tps()),
                    r.chaos.fault_steps.to_string(),
                    format_chaos(&r.chaos),
                    "ok".into(),
                ]);
            }
            Err(_) => t.row(vec![
                label.clone(),
                "-".into(),
                "-".into(),
                "-".into(),
                "-".into(),
                "-".into(),
                "-".into(),
                "unrecoverable".into(),
            ]),
        }
        results.push((label, outcome));
    }
    print_table(
        &format!(
            "chaos | {} | {} | {n} requests | faults: {}",
            engine.system.name,
            scenario.label(),
            faults.label()
        ),
        &t,
    );
    for (label, outcome) in &results {
        if let Err(e) = outcome {
            println!("{label}: {e}");
        }
    }

    if let Some(out) = args.get("out") {
        let planners = results.iter().map(|(label, outcome)| match outcome {
            Ok(r) => Json::obj(vec![
                ("planner", Json::str(&r.planner)),
                ("makespan_s", Json::num(r.makespan_s)),
                ("p50_latency_s", Json::num(r.request_latency.p50)),
                ("p99_latency_s", Json::num(r.request_latency.p99)),
                ("throughput_tps", Json::num(r.throughput_tps())),
                ("completed", Json::num(r.completed as f64)),
                ("placement", placement_to_json(&r.placement)),
                ("chaos", chaos_stats_to_json(&r.chaos)),
            ]),
            Err(e) => {
                Json::obj(vec![("planner", Json::str(label)), ("error", Json::str(e))])
            }
        });
        let json = Json::obj(vec![
            ("schema_version", Json::num(SCHEMA_VERSION as f64)),
            ("system", Json::str(&engine.system.name)),
            ("scenario", Json::str(&scenario.label())),
            ("faults", Json::str(&faults.spec())),
            ("requests", Json::num(n as f64)),
            ("seed", Json::num(seed as f64)),
            ("planners", Json::arr(planners)),
        ]);
        std::fs::write(out, json.to_string_pretty()).map_err(|e| e.to_string())?;
        println!("wrote {out}");
    }
    write_trace(&tracer, args)
}

/// `llep fleet`: simulate N serving replicas behind a global router on
/// one virtual timeline, optionally killing/recovering whole replicas
/// (`--faults "fail:r=1,at=0.02"` or correlated `burst:r=1-3,at=0.02`)
/// and optionally under overload protection (`--admission`,
/// `--queue-cap`, `--retries`, ...). The command fails (non-zero exit)
/// when any request is lost (`completed + shed == requests` under
/// protection, `completed == requests` otherwise), the summed token
/// ledger is inexact, or goodput is zero — the CI smoke contract.
fn cmd_fleet(args: &llep::util::cli::Args) -> Result<(), String> {
    let (engine, llep) = engine_from_args(args)?;
    let scenario = scenario_from_args(args)?;
    let seed = args.get_usize("seed", 0)? as u64;
    let n_replicas = args.get_usize("replicas", 2)?;
    if n_replicas == 0 {
        return Err("--replicas must be at least 1".into());
    }
    let router = RouterPolicy::parse(&args.get_or("router", "least-queue"))?;
    let workload = Workload::parse(&args.get_or("workload", "poisson"))?;
    // Every replica runs the same planner policy (heterogeneity comes
    // from --speeds and per-replica chaos, not mixed planners).
    let planner_spec = match args.get("planner") {
        Some(spec) => resolve_planner_arg(spec)?.spec(),
        None => PlannerKind::Llep(llep).boxed().spec(),
    };
    let speeds: Vec<f64> = match args.get("speeds") {
        Some(list) => list
            .split(',')
            .map(|x| {
                x.trim()
                    .parse::<f64>()
                    .map_err(|_| format!("--speeds: bad multiplier {x:?}"))
            })
            .collect::<Result<_, _>>()?,
        None => vec![1.0; n_replicas],
    };
    if speeds.len() != n_replicas {
        return Err(format!(
            "--speeds lists {} multipliers but --replicas is {n_replicas}",
            speeds.len()
        ));
    }
    let replicas: Vec<ReplicaConfig> = speeds
        .iter()
        .map(|&s| ReplicaConfig::default().with_planner(&planner_spec).with_speed(s))
        .collect();
    let budget = args.get_usize("tokens", 8192)? * engine.system.devices;
    // The template engine carries the tracer; FleetSim re-tags each
    // replica with its own pid and keeps the router on this one.
    let tracer = tracer_from_args(args);
    let engine = engine.with_tracer(tracer.clone());
    let mut sim = FleetSim::new(engine, scenario.clone(), replicas, budget)
        .with_router(router)
        .with_workload(workload);
    let faults = match args.get("faults") {
        Some(spec) => {
            let plan = FleetFaultPlan::parse(spec)?;
            sim = sim.with_faults(plan.clone());
            Some(plan)
        }
        None => None,
    };
    let deadline = args.get_f64("deadline", 0.0)?;
    if deadline > 0.0 {
        sim = sim.with_deadline(deadline);
    }

    // Any overload knob (or --admission) switches the fleet into the
    // protected regime; the knobs compose into one OverloadConfig spec
    // so CLI runs and `OverloadConfig::parse` agree exactly.
    let admission = args.has_flag("admission");
    let overload_knobs = [
        ("queue-cap", "queue-cap"),
        ("frontend-cap", "frontend-cap"),
        ("retries", "retries"),
        ("backoff", "backoff"),
        ("backoff-cap", "backoff-cap"),
        ("breaker-after", "breaker-after"),
        ("breaker-cooldown", "cooldown"),
    ];
    let protected = admission || overload_knobs.iter().any(|(cli, _)| args.get(cli).is_some());
    if protected {
        if admission && !(deadline > 0.0) {
            return Err("--admission requires --deadline (it sheds requests that cannot \
                        finish within the deadline)"
                .into());
        }
        let mut parts = vec![format!("admission={}", if admission { 1 } else { 0 })];
        for (cli, key) in overload_knobs {
            if let Some(v) = args.get(cli) {
                parts.push(format!("{key}={v}"));
            }
        }
        sim = sim.with_overload(OverloadConfig::parse(&parts.join(","))?);
    }

    let report = sim.try_run(seed)?;

    let fault_note = faults
        .as_ref()
        .map(|f| format!(" | faults: {}", f.spec()))
        .unwrap_or_default();
    print_table(
        &format!(
            "fleet | {n_replicas} replicas | router {} | {} | {}{fault_note}",
            report.router,
            report.workload,
            scenario.label()
        ),
        &fleet_replica_table(&report),
    );
    println!(
        "requests {}/{} | makespan {} | TTFT p50 {} p99 {} | latency p99 {} | \
         goodput {:.0} tok/s | throughput {:.0} tok/s",
        report.completed,
        report.requests,
        format_secs(report.makespan_s),
        format_secs(report.ttft.p50),
        format_secs(report.ttft.p99),
        format_secs(report.request_latency.p99),
        report.goodput_tps,
        report.throughput_tps
    );
    if let Some(d) = report.deadline_s {
        println!(
            "SLO: {}/{} requests within {} ({:.0}%)",
            report.on_time,
            report.requests,
            format_secs(d),
            100.0 * report.on_time as f64 / report.requests.max(1) as f64
        );
    }
    if report.replica_failures + report.replica_recoveries > 0 {
        println!(
            "replica chaos: {} failure(s), {} recover(y/ies), {} request(s) requeued \
             (max {} per request)",
            report.replica_failures,
            report.replica_recoveries,
            report.requeued_requests,
            report.max_requeues
        );
    }
    if report.protected {
        let o = &report.overload;
        println!(
            "overload: shed {}/{} (deadline {}, backpressure {}, retries {}) | \
             {} retr(y/ies), backoff total {} | breaker: {} open(s), {} probe(s), \
             frontend peak {}",
            report.shed,
            report.requests,
            o.shed_deadline,
            o.shed_frontend,
            o.shed_retries,
            o.retries,
            format_secs(o.backoff_total_s),
            o.breaker_opens,
            o.breaker_probes,
            o.frontend_peak_depth
        );
    }

    if let Some(out) = args.get("out") {
        let json = fleet_report_to_json(&report);
        std::fs::write(out, json.to_string_pretty()).map_err(|e| e.to_string())?;
        println!("wrote {out}");
    }
    write_trace(&tracer, args)?;

    // Hard contract, enforced by exit code (the CI smoke step): nothing
    // lost, exact accounting, useful work actually delivered. Under
    // overload protection shedding is deliberate, so the ledger relaxes
    // to `completed + shed == requests`; unprotected stays strict.
    if report.protected {
        if report.completed + report.shed != report.requests {
            return Err(format!(
                "fleet lost requests: {} completed + {} shed != {}",
                report.completed, report.shed, report.requests
            ));
        }
    } else if report.completed != report.requests {
        return Err(format!(
            "fleet lost requests: {}/{} completed",
            report.completed, report.requests
        ));
    }
    if !report.tokens.is_exact() {
        return Err(format!("fleet token ledger inexact: {:?}", report.tokens));
    }
    for (i, p) in report.replicas.iter().enumerate() {
        if !p.tokens.is_exact() {
            return Err(format!("replica {i} token ledger inexact: {:?}", p.tokens));
        }
    }
    if !(report.goodput_tps > 0.0) {
        return Err("fleet goodput is zero — no request met the deadline".into());
    }
    Ok(())
}

/// `llep bench`: run a pinned micro-benchmark suite. `--out` writes the
/// fresh medians as JSON (`BENCH_<suite>.json` by convention); `--check`
/// compares against a checked-in pin with a tolerance band — a missing
/// pin bootstraps (like `tune --pin`), an existing one fails the command
/// on any median regression beyond the band or any vanished case. This
/// is the rebar-style gate that keeps the zero-allocation hot path's
/// speedups locked in instead of anecdotal.
fn cmd_bench(args: &llep::util::cli::Args) -> Result<(), String> {
    use llep::harness::hotpath;
    use llep::util::benchkit::{format_ns, quick_requested, BenchSuite};

    let suite_name = args.get_or("suite", "hotpath");
    if suite_name != "hotpath" {
        return Err(format!("unknown bench suite {suite_name:?} (available: hotpath)"));
    }
    let quick = args.has_flag("quick") || quick_requested();
    let tolerance = args.get_f64("tolerance", hotpath::DEFAULT_TOLERANCE)?;
    println!("== bench suite {suite_name} ({}) ==", if quick { "quick" } else { "full" });
    let suite = hotpath::hotpath_suite(quick);

    // The alloc-vs-scratch ratio is the headline of this suite: print it
    // whenever both cases ran.
    if let (Some(scratch), Some(alloc)) = (
        suite.get("plan/llep/skewed/scratch/N=128/P=8"),
        suite.get("plan/llep/skewed/alloc/N=128/P=8"),
    ) {
        println!(
            "\nskewed planner microbench: scratch {} vs alloc {} ({:.2}x)",
            format_ns(scratch.median_ns),
            format_ns(alloc.median_ns),
            alloc.median_ns / scratch.median_ns.max(1.0)
        );
    }

    if let Some(out) = args.get("out") {
        suite.save(std::path::Path::new(out))?;
        println!("wrote {out}");
    }

    let Some(pin_path) = args.get("check") else { return Ok(()) };
    if !std::path::Path::new(pin_path).exists() {
        // Bootstrap only on a genuinely absent pin. A pin that exists
        // but fails to load (truncated, merge-conflicted) must FAIL the
        // gate below, not be silently overwritten with fresh medians.
        suite.save(std::path::Path::new(pin_path))?;
        println!("bench pin bootstrapped: {pin_path} — commit it to arm the regression gate");
        return Ok(());
    }
    match BenchSuite::load(std::path::Path::new(pin_path)) {
        Err(e) => Err(format!(
            "bench pin {pin_path} exists but is unreadable ({e}); refusing to overwrite a \
             corrupt baseline — fix or delete it, then re-run with --check to re-bootstrap"
        )),
        Ok(pin) => {
            let cmp = suite.compare(&pin);
            println!(
                "\ncheck vs {pin_path} (pinned at rev {}, tolerance {:.0}%):",
                pin.git_rev,
                tolerance * 100.0
            );
            for d in &cmp.deltas {
                let status = if d.regressed(tolerance) { "REGRESSED" } else { "ok" };
                println!(
                    "  {:<42} pin {:>12}  now {:>12}  {:>6.2}x  {status}",
                    d.name,
                    format_ns(d.pinned_ns),
                    format_ns(d.current_ns),
                    d.ratio()
                );
            }
            for name in &cmp.missing {
                println!("  {name:<42} MISSING from this run");
            }
            // Cases measured this run but absent from the pin run
            // un-gated — say so, so a stale pin is visible, not silent.
            for r in &suite.results {
                if pin.get(&r.name).is_none() {
                    println!(
                        "  {:<42} now {:>12}  NEW (not pinned — refresh the pin to gate it)",
                        r.name,
                        format_ns(r.median_ns)
                    );
                }
            }
            if cmp.passes(tolerance) {
                println!("bench pin ok: no case regressed beyond {:.0}%", tolerance * 100.0);
                Ok(())
            } else {
                Err(format!(
                    "bench regression vs {pin_path}: {} case(s) beyond the {:.0}% band, {} \
                     missing. If the slowdown is intentional, delete the pin, re-run \
                     `llep bench --suite {suite_name} --check {pin_path}` and commit the \
                     refreshed file.",
                    cmp.regressions(tolerance).len(),
                    tolerance * 100.0,
                    cmp.missing.len()
                ))
            }
        }
    }
}

fn cmd_info() -> Result<(), String> {
    println!("model presets:");
    for p in ModelPreset::ALL {
        let m = ModelConfig::preset(p);
        println!(
            "  {:<14} N={:<4} K={} D={:<5} H={:<5} layers={}",
            m.name, m.num_experts, m.top_k, m.d_model, m.d_ff, m.num_layers
        );
    }
    println!("\nsystem presets (also the builtin `tune --profile` names):");
    for p in SystemPreset::ALL {
        let s = SystemConfig::preset(p);
        let het = if s.device_speeds.is_empty() {
            String::new()
        } else {
            format!("  speeds={:?}", s.device_speeds)
        };
        println!(
            "  {:<15} P={:<3} {}/node  mem={}  peak={:.0e} FLOP/s{het}",
            s.name,
            s.devices,
            s.devices_per_node,
            format_bytes(s.mem_capacity_bytes),
            s.gemm.peak_flops
        );
    }
    println!(
        "\nfault events (--faults \"ev;ev;...\", or a TOML path with [chaos] faults=\"...\"):"
    );
    println!("  slow:dev=D,x=F[,from=S,until=S]   divide device D's speed by F");
    println!("  stall:dev=D,at=S[,steps=N]        device D dead for N steps, then back");
    println!("  fail:dev=D,at=S                   permanent failure (until recover)");
    println!("  recover:dev=D,at=S                device D rejoins the pool");
    println!("  link:x=F[,from=S,until=S]         divide link bandwidths by F");
    println!("  link:dev=D,x=F[,from=S,until=S]   ... only transfers touching device D");
    println!("  jitter:amp=A,seed=K[,from,until]  seeded per-(step,device) speed noise");
    println!("\nplanners (--planner <spec>; examples are canonical registry specs):");
    for e in Registry::builtin().entries() {
        let dims = if e.params.is_empty() {
            String::new()
        } else {
            let keys: Vec<&str> = e.params.iter().map(|p| p.key).collect();
            format!("  [tunable: {}]", keys.join(", "))
        };
        println!("  {:<8} {:<55} e.g. {}{}", e.name, e.help, e.example, dims);
    }
    println!(
        "  {:<8} {:<55} e.g. {}",
        "cached",
        "cross-step plan-reuse decorator (wraps any spec)",
        "cached(ep):drift=0.05,every=0,q=1024,repair=0.15"
    );
    println!(
        "  {:<8} {:<55} e.g. {}",
        "placed",
        "persistent expert re-layout decorator (wraps any spec)",
        "placed(llep):ema=0.25,budget=4,horizon=32,standby=1"
    );
    println!("\ntimeline tracing (--trace out.json on run/serve/chaos/fleet):");
    println!("  records the virtual-clock execution timeline — per-device compute spans,");
    println!("  plan/cache-outcome instants, weight-transfer and router flow arrows, chaos");
    println!("  fault windows — as Chrome trace-event JSON; open in https://ui.perfetto.dev");
    println!("  or chrome://tracing. (`replay --trace` instead names its routing-trace input.)");
    print_artifacts_info();
    Ok(())
}

#[cfg(feature = "pjrt")]
fn print_artifacts_info() {
    match llep::runtime::Runtime::open(&llep::runtime::Runtime::default_dir()) {
        Ok(rt) => println!("\nartifacts: {} entries on {}", rt.len(), rt.platform()),
        Err(e) => println!("\nartifacts: unavailable ({e})"),
    }
}

#[cfg(not(feature = "pjrt"))]
fn print_artifacts_info() {
    println!("\nartifacts: unavailable (built without the `pjrt` feature)");
}
