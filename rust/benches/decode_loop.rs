//! Bench: decode-dominated serving with cross-step plan reuse.
//!
//! Two measurements:
//!
//! 1. **Planner microbench** — fresh LLEP planning vs a `CachedPlanner`
//!    hit on an unchanged load matrix. The hit replays the cached plan
//!    via the O(segments) retarget path, so its wall time must sit well
//!    below a fresh plan's sort+spill, while the engine prices both
//!    bit-identically (checked and printed below).
//! 2. **Decode loop** — `ContinuousBatchSim` in the steady decode regime
//!    with and without the cache: the report shows the hit rate and the
//!    p50 per-step planning time dropping while TPOT accounting stays
//!    honest (priced == admitted).
//!
//! Run: `cargo bench --bench decode_loop` (add `--quick` to shrink).

use llep::coordinator::ContinuousBatchSim;
use llep::metrics::{format_cache, format_secs, planner_comparison_table, Table};
use llep::prelude::*;
use llep::util::benchkit::{bb, quick_requested, Bencher};

fn main() {
    let quick = quick_requested();
    let engine = Engine::modeled(
        ModelConfig::preset(ModelPreset::Fig1Layer),
        SystemConfig::preset(SystemPreset::H200x8),
    );

    // ---- 1. fresh plan vs cached hit on unchanged loads ------------------
    let mut rng = Rng::new(1);
    let lm = Scenario::concentrated(0.9, 1).generate_loads(&engine.model, 8, 4096, &mut rng);
    let loads = lm.expert_loads();
    let llep = PlannerKind::llep_default();

    let mut b = if quick { Bencher::quick() } else { Bencher::new() };
    let fresh = b.bench("plan/fresh/llep/N=128", || bb(llep.plan(8, &loads, Some(&engine.topo))));

    let cached = CachedPlanner::new(PlannerKind::llep_default().boxed());
    let _ = cached.plan(8, &loads, Some(&engine.topo)); // prime: one miss
    let hit = b.bench("plan/cached-hit/llep/N=128", || {
        bb(cached.plan(8, &loads, Some(&engine.topo)))
    });
    println!(
        "\ncached hit {} vs fresh replan {} -> {:.1}x less planner time on the decode \
         critical path{}",
        format_secs(hit.mean_s()),
        format_secs(fresh.mean_s()),
        fresh.mean_ns / hit.mean_ns.max(1.0),
        if hit.mean_ns < fresh.mean_ns { "" } else { "  [UNEXPECTED: hit not cheaper]" }
    );

    // Identical pricing on unchanged loads (the honesty contract): every
    // deterministic quantity agrees between cached-hit and fresh steps.
    let fresh_step = engine.run_step_loads(&lm, &llep);
    let hit_step = engine.run_step_loads(&lm, &cached);
    let identical = hit_step.device_compute_s == fresh_step.device_compute_s
        && hit_step.device_peak_bytes == fresh_step.device_peak_bytes
        && hit_step.bytes_dispatch == fresh_step.bytes_dispatch
        && hit_step.bytes_weights == fresh_step.bytes_weights
        && hit_step.gemm_calls == fresh_step.gemm_calls;
    assert!(identical, "cached-vs-fresh pricing must be identical on unchanged loads");
    assert!(hit_step.cache.hits == 1, "step must have been served from the cache");
    println!(
        "pricing identical on unchanged loads: {identical} (compute max {}, peak {} B)\n",
        format_secs(hit_step.phases.compute_s),
        hit_step.max_peak_bytes()
    );

    // Full-model planner comparison rows (EP baseline, fresh LLEP, and a
    // cache hit serving the same step).
    let lms = std::slice::from_ref(&lm);
    let ep_model = engine.run_model(lms, &PlannerKind::StandardEp).unwrap();
    let ll_model = engine.run_model(lms, &PlannerKind::llep_default()).unwrap();
    let hit_model = engine.run_model(lms, &cached).unwrap(); // warm cache -> hit
    println!("{}", planner_comparison_table(&[ep_model, ll_model, hit_model]).render());

    // ---- 2. decode-dominated continuous batching -------------------------
    // Short prompts, long decodes: after the brief prefill phase every
    // step is a small decode batch with a near-stationary routing
    // signature — the regime where plan reuse pays.
    let n_req = if quick { 12 } else { 32 };
    let mut reqs_rng = Rng::new(2);
    let requests =
        ContinuousBatchSim::requests(n_req, 0.00002, (64, 128), (96, 160), &mut reqs_rng);

    let scenario = Scenario::concentrated(0.9, 1);
    let plain = ContinuousBatchSim::new(
        engine.clone(),
        PlannerKind::llep_default(),
        scenario.clone(),
        16_384,
    );
    let reuse = ContinuousBatchSim::with_planner(
        engine.clone(),
        Box::new(
            CachedPlanner::new(PlannerKind::llep_default().boxed())
                .with_drift_threshold(0.25)
                .with_replan_every(64),
        ),
        scenario,
        16_384,
    );

    let r_plain = plain.run(&requests, &mut Rng::new(3));
    let r_reuse = reuse.run(&requests, &mut Rng::new(3));

    let mut t = Table::new(&[
        "planner", "steps", "tpot p50", "p50 plan/step", "plan cache", "priced==admitted",
    ]);
    for r in [&r_plain, &r_reuse] {
        t.row(vec![
            r.planner.clone(),
            r.steps.to_string(),
            format_secs(r.tpot.p50),
            format_secs(r.plan_time.p50),
            format_cache(&r.plan_cache),
            r.tokens.is_exact().to_string(),
        ]);
    }
    println!("Decode loop — {n_req} requests, ~128 decode steps each, P=8\n");
    println!("{}", t.render());
    assert!(r_plain.tokens.is_exact() && r_reuse.tokens.is_exact());
    assert!(
        r_reuse.plan_cache.hits > r_reuse.plan_cache.misses,
        "steady decode must mostly reuse: {:?}",
        r_reuse.plan_cache
    );
    println!(
        "reused-plan steps price {} p50 planning vs {} replanned — {:.1}x off the decode \
         critical path at {:.0}% hit rate",
        format_secs(r_reuse.plan_time.p50),
        format_secs(r_plain.plan_time.p50),
        r_plain.plan_time.p50 / r_reuse.plan_time.p50.max(1e-12),
        r_reuse.plan_cache.hit_rate() * 100.0
    );
}
