//! Bench: overload protection under a correlated replica burst.
//!
//! Three measurements:
//!
//! 1. **Protected vs unprotected** — a bursty workload, a tight SLO
//!    deadline, and a correlated 2-of-3 replica burst outage. The
//!    unprotected fleet drains everything onto the survivor's unbounded
//!    queue; the protected fleet (admission + queue caps + retry/backoff
//!    + breakers) sheds the unservable tail. Prints p99 TTFT, goodput,
//!    and the shed breakdown side by side.
//! 2. **Shed-cause breakdown** — where the protected run's shed requests
//!    went (deadline admission, backpressure, retry budget), plus
//!    breaker opens/probes — the exactness contract `completed + shed ==
//!    requests` is asserted, as is the summed token ledger.
//! 3. **Simulator wall time** — host-side cost of one protected fleet
//!    run (the overload path must stay cheap enough for sweeps).
//!
//! Run: `cargo bench --bench fleet_overload` (add `--quick` to shrink).

use llep::fleet::{FleetFaultPlan, FleetSim, OverloadConfig, ReplicaConfig, RouterPolicy, Workload};
use llep::metrics::{format_secs, Table};
use llep::prelude::*;
use llep::util::benchkit::{bb, quick_requested, Bencher};
use llep::util::rng::Rng;

fn main() {
    let quick = quick_requested();
    let engine = Engine::modeled(
        ModelConfig::preset(ModelPreset::Fig1Layer),
        SystemConfig::preset(SystemPreset::H200x8),
    );
    let scenario = Scenario::concentrated(0.8, 4);
    let n_req = if quick { 48 } else { 96 };
    let burst = n_req / 4;
    let wl = Workload::parse(&format!(
        "bursty:n={n_req},ia=0.0001,burst={burst},every={burst},prompt=512-2048,decode=2-6"
    ))
    .unwrap();
    let seed = 21;
    let replicas = || vec![ReplicaConfig::default(); 3];

    // Calibrate the deadline and the outage window from a healthy run so
    // the comparison is self-scaling, never hand-tuned to the cost model.
    let healthy = FleetSim::new(engine.clone(), scenario.clone(), replicas(), 16_384)
        .with_workload(wl.clone())
        .try_run(seed)
        .expect("healthy fleet run");
    let deadline = healthy.request_latency.p99 * 1.5;
    let arrivals = wl.generate(&mut Rng::new(seed));
    let kill_at = arrivals[n_req / 2 - 1].arrival_s + 1e-6;
    let outage = (healthy.makespan_s * 64.0).max(1.0);
    let faults = || {
        FleetFaultPlan::parse(&format!("burst:r=1-2,at={kill_at},for={outage}"))
            .expect("burst plan")
    };

    // ---- 1. protected vs unprotected -------------------------------------
    let unprotected = FleetSim::new(engine.clone(), scenario.clone(), replicas(), 16_384)
        .with_workload(wl.clone())
        .with_faults(faults())
        .with_deadline(deadline)
        .try_run(seed)
        .expect("unprotected fleet run");
    let overload = OverloadConfig::parse(
        "queue-cap=4,frontend-cap=6,retries=2,backoff=0.0002,backoff-cap=0.001,\
         breaker-after=1,cooldown=0.002",
    )
    .unwrap();
    let protected = FleetSim::new(engine.clone(), scenario.clone(), replicas(), 16_384)
        .with_workload(wl.clone())
        .with_faults(faults())
        .with_deadline(deadline)
        .with_overload(overload.clone())
        .try_run(seed)
        .expect("protected fleet run");

    let mut t = Table::new(&["fleet", "completed", "shed", "p99 TTFT", "goodput", "makespan"]);
    for (name, r) in [("unprotected", &unprotected), ("protected", &protected)] {
        assert_eq!(r.completed + r.shed, r.requests, "{name}: lost requests");
        assert!(r.tokens.is_exact(), "{name}: summed ledger {:?}", r.tokens);
        t.row(vec![
            name.to_string(),
            format!("{}/{}", r.completed, r.requests),
            format!("{}", r.shed),
            format_secs(r.ttft.p99),
            format!("{:.0} tok/s", r.goodput_tps),
            format_secs(r.makespan_s),
        ]);
    }
    println!(
        "Overload drill: replicas 1-2 die at {} | deadline {} | {n_req} requests\n",
        format_secs(kill_at),
        format_secs(deadline)
    );
    println!("{}", t.render());

    // ---- 2. shed-cause breakdown -----------------------------------------
    let o = &protected.overload;
    assert!(protected.shed > 0, "the drill must force shedding");
    assert_eq!(protected.shed, o.shed(), "shed causes partition the shed count");
    println!(
        "\nprotected shed breakdown: deadline {} | backpressure {} | retries {} \
         | {} retr(y/ies), backoff total {} | breaker: {} open(s), {} probe(s)",
        o.shed_deadline,
        o.shed_frontend,
        o.shed_retries,
        o.retries,
        format_secs(o.backoff_total_s),
        o.breaker_opens,
        o.breaker_probes
    );

    // ---- 3. simulator wall time ------------------------------------------
    let mut b = if quick { Bencher::quick() } else { Bencher::new() };
    let sim = FleetSim::new(engine, scenario, replicas(), 16_384)
        .with_workload(wl)
        .with_router(RouterPolicy::LeastQueue)
        .with_faults(faults())
        .with_deadline(deadline)
        .with_overload(overload);
    let wall = b.bench("fleet/overload/run", || bb(sim.try_run(seed).unwrap().completed));
    println!(
        "\nprotected fleet run wall time {} for {n_req} requests x 3 replicas",
        format_secs(wall.mean_s())
    );
}
