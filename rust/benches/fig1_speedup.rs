//! Bench: Fig. 1a/1b — EP vs LLEP latency and memory on the 128-expert
//! layer across the paper's imbalance grid, plus wall-time of the
//! simulation itself.
//!
//! Run: `cargo bench --bench fig1_speedup` (add `--quick` to shrink).

use llep::harness::{compare, paper_scenarios};
use llep::metrics::{format_bytes, format_secs, Table};
use llep::prelude::*;
use llep::util::benchkit::{quick_requested, Bencher};

fn main() {
    let engine = Engine::modeled(
        ModelConfig::preset(ModelPreset::Fig1Layer),
        SystemConfig::preset(SystemPreset::H200x8),
    );
    let llep = LlepConfig::default();
    let tokens = if quick_requested() { 8192 } else { 32_768 };

    let mut table = Table::new(&[
        "scenario", "EP latency", "LLEP latency", "speedup", "EP peak", "LLEP peak",
    ]);
    for sc in paper_scenarios(engine.model.num_experts) {
        let (speedup, ep, ll) = compare(&engine, &sc, tokens, &llep, 1);
        table.row(vec![
            sc.label(),
            format_secs(ep.latency_s),
            format_secs(ll.latency_s),
            format!("{speedup:.2}x"),
            format_bytes(ep.max_peak_bytes()),
            format_bytes(ll.max_peak_bytes()),
        ]);
    }
    println!("Fig 1a/1b — 128 experts, top-4, D=2048, P=8, {tokens} tokens/device\n");
    println!("{}", table.render());

    // Wall-time of the end-to-end simulated step (plan + price), the
    // quantity the perf pass optimizes.
    let mut b = if quick_requested() { Bencher::quick() } else { Bencher::new() };
    let mut rng = Rng::new(2);
    let lm_hot =
        Scenario::concentrated(0.95, 1).generate_loads(&engine.model, 8, tokens, &mut rng);
    let lm_bal = Scenario::balanced().generate_loads(&engine.model, 8, tokens, &mut rng);
    b.bench("sim_step/ep/95into1", || engine.run_step_loads(&lm_hot, &PlannerKind::StandardEp));
    b.bench("sim_step/llep/95into1", || {
        engine.run_step_loads(&lm_hot, &PlannerKind::llep_default())
    });
    b.bench("sim_step/llep/balanced", || {
        engine.run_step_loads(&lm_bal, &PlannerKind::llep_default())
    });
}
