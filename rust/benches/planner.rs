//! Bench: planner hot paths — LLA planning latency (it sits on the
//! critical path of every step, paper Alg. 4), dispatch chunk building,
//! and the native GEMM kernel. These are the targets of the perf pass
//! (EXPERIMENTS.md §Perf).
//!
//! The pinned `hotpath` suite (same cases as `llep bench --suite
//! hotpath`, medians gated against `BENCH_planner.json` in CI) runs
//! first; the sweeps below add problem-size coverage on top.
//!
//! Run: `cargo bench --bench planner` (add `--quick` to shrink).

use llep::exec::dispatch;
use llep::harness::hotpath::hotpath_suite;
use llep::planner::{plan_ep, plan_eplb, plan_llep, plan_llep_scratch, PlanScratch};
use llep::prelude::*;
use llep::tensor::{matmul, Mat};
use llep::util::benchkit::{bb, quick_requested, Bencher};

fn main() {
    let quick = quick_requested();

    // --- the pinned hotpath suite (skewed-scenario headline) ---------------
    let suite = hotpath_suite(quick);

    // The O(Δ) claim, checked rather than asserted in prose: repairing a
    // drifted plan must cost well under half a fresh replan of the same
    // loads. Both medians come from the suite just measured above.
    let repair = suite.get("plan/cached-repair/drift/N=128/P=8").expect("repair case").median_ns;
    let fresh =
        suite.get("plan/drift-fresh-replan/drift/N=128/P=8").expect("fresh case").median_ns;
    assert!(
        repair < 0.5 * fresh,
        "delta repair ({repair:.0} ns) is not <0.5x a fresh replan ({fresh:.0} ns)"
    );
    println!("repair/fresh ratio: {:.2}", repair / fresh);

    let mut b = if quick { Bencher::quick() } else { Bencher::new() };

    // --- LLA planning latency across problem sizes -------------------------
    // `lla/...` plans steady-state (arena reused, plans recycled);
    // `lla-alloc/...` pays a fresh arena per call for comparison.
    let mut scratch = PlanScratch::new();
    for &(n, p) in &[(32usize, 8usize), (128, 8), (256, 8), (384, 8), (128, 16)] {
        let mut model = ModelConfig::preset(ModelPreset::Fig1Layer);
        model.num_experts = n;
        let mut rng = Rng::new(n as u64);
        let lm = Scenario::concentrated(0.9, 4.min(n)).generate_loads(&model, p, 32_768, &mut rng);
        let loads = lm.expert_loads();
        let cfg = LlepConfig::default();
        b.bench(&format!("lla/N={n}/P={p}"), || {
            let plan = plan_llep_scratch(&cfg, n, p, &loads, None, None, &mut scratch);
            let k = plan.transfers.len();
            scratch.recycle(plan);
            k
        });
        b.bench(&format!("lla-alloc/N={n}/P={p}"), || {
            // A fresh arena per call IS the historical allocating path
            // (plan_llep itself reuses the thread-local arena).
            let mut fresh = PlanScratch::new();
            bb(plan_llep_scratch(&cfg, n, p, &loads, None, None, &mut fresh))
        });
        b.bench(&format!("ep/N={n}/P={p}"), || bb(plan_ep(n, p, &loads)));
        b.bench(&format!("eplb/N={n}/P={p}"), || bb(plan_eplb(p, n, p, &loads, &loads)));
    }

    // --- dispatch chunk building -------------------------------------------
    let model = ModelConfig::preset(ModelPreset::GptOss120b);
    let mut rng = Rng::new(7);
    let lm = Scenario::concentrated(0.8, 4).generate_loads(&model, 8, 32_768, &mut rng);
    let loads = lm.expert_loads();
    let plan = plan_llep(&LlepConfig::default(), model.num_experts, 8, &loads, None);
    b.bench("dispatch/chunks/N=128", || bb(dispatch::chunks(&plan, &lm)));
    b.bench("dispatch/device_work/N=128", || bb(dispatch::device_work(&plan, &lm)));
    let cs = dispatch::chunks(&plan, &lm);
    b.bench("dispatch/bytes/N=128", || bb(dispatch::dispatch_bytes(&cs, 8, 5760)));

    // --- native GEMM kernel --------------------------------------------------
    let mut rng = Rng::new(8);
    for &(m, k, n) in &[(64usize, 64usize, 64usize), (256, 64, 128), (512, 128, 256)] {
        let a = Mat::randn(m, k, 0.1, &mut rng);
        let w = Mat::randn(k, n, 0.1, &mut rng);
        b.bench(&format!("native_gemm/{m}x{k}x{n}"), || bb(matmul(&a, &w)));
    }

    // --- full modeled step (plan + price) ------------------------------------
    let engine = Engine::modeled(
        ModelConfig::preset(ModelPreset::GptOss120b),
        SystemConfig::preset(SystemPreset::H200x8),
    );
    b.bench("engine/run_step_loads/llep", || {
        bb(engine.run_step_loads(&lm, &PlannerKind::llep_default()))
    });
}
