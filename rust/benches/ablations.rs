//! Bench: the paper's ablation studies — Fig. 6a (batch size), Fig. 6b
//! (alpha), Fig. 7a (lambda), Fig. 7b (hidden size), Fig. 9 (number of
//! experts) — plus two ablations the paper discusses in prose: EPLB
//! under drifting routing, and the intra-node spill preference.
//!
//! Run: `cargo bench --bench ablations` (add `--quick` to shrink).

use llep::coordinator::{RunSummary, Runner};
use llep::harness;
use llep::metrics::Table;
use llep::prelude::*;
use llep::routing::RoutingTrace;
use llep::util::benchkit::quick_requested;

fn main() {
    println!("Fig 6a — speedup vs batch size (4 hot experts)\n{}", harness::fig_6a().render());
    println!("Fig 6b — speedup vs alpha\n{}", harness::fig_6b().render());
    println!("Fig 7a — speedup vs lambda (B=8K)\n{}", harness::fig_7a().render());
    println!("Fig 7b — speedup vs hidden size\n{}", harness::fig_7b().render());
    println!("Fig 9 — speedup vs number of experts\n{}", harness::fig_9().render());

    // --- Ablation: EPLB vs LLEP under drifting routing (paper §3.1's
    // criticism of time-delayed statistics) --------------------------------
    let model = ModelConfig::preset(ModelPreset::GptOss120b);
    let engine = Engine::modeled(model.clone(), SystemConfig::preset(SystemPreset::H200x8));
    let batches = if quick_requested() { 6 } else { 16 };
    let mut rng = Rng::new(3);
    let mut trace = RoutingTrace::new("drift", model.num_experts, model.top_k);
    for _ in 0..batches {
        trace
            .push(Scenario::drifting(17, 0.4, 0.6).generate_loads(&model, 8, 16_384, &mut rng))
            .unwrap();
    }
    let mut t = Table::new(&["policy", "total latency (s)", "peak mem (GiB)"]);
    for kind in [
        PlannerKind::StandardEp,
        PlannerKind::ChunkedEp { chunk_tokens: 8192 },
        PlannerKind::Eplb { replicas: 8 },
        PlannerKind::llep_default(),
    ] {
        let mut runner = Runner::new(engine.clone(), kind);
        let s = RunSummary::of(&runner.run_trace(&trace));
        t.row(vec![
            s.planner.clone(),
            format!("{:.4}", s.total_latency_s),
            format!("{:.2}", s.peak_bytes as f64 / (1u64 << 30) as f64),
        ]);
    }
    println!(
        "Ablation — drifting hotspot, {batches} batches (EPLB uses stale stats)\n{}",
        t.render()
    );

    // --- Ablation: intra-node spill preference on 2 nodes ------------------
    let model16 = ModelConfig::preset(ModelPreset::GptOss120b);
    let sys16 = SystemConfig::preset(SystemPreset::H200x16TwoNodes);
    let engine16 = Engine::modeled(model16.clone(), sys16);
    let mut rng = Rng::new(4);
    let lm = Scenario::concentrated(0.9, 4).generate_loads(&model16, 16, 16_384, &mut rng);
    let ep = engine16.run_step_loads(&lm, &PlannerKind::StandardEp);
    let ll = engine16.run_step_loads(&lm, &PlannerKind::llep_default());
    println!("Ablation — 2-node (16 GPU) topology, 90% into 4 experts:");
    println!(
        "  EP {:.4}s vs LLEP {:.4}s -> {:.2}x (intra-node spills preferred on load ties)",
        ep.latency_s,
        ll.latency_s,
        ep.latency_s / ll.latency_s
    );

    // --- Ablation: static LPT expert placement (locality-aware placement
    // baseline, Hu et al. 2025) vs LLEP, persistent vs drifting hotspot ---
    {
        use llep::planner::Placement;
        let model = ModelConfig::preset(ModelPreset::GptOss120b);
        let engine = Engine::modeled(model.clone(), SystemConfig::preset(SystemPreset::H200x8));
        let mut rng = Rng::new(6);
        let mut t = Table::new(&["regime", "EP", "EP+LPT placement", "LLEP"]);
        // 60% of load into 4 experts that are COLOCATED on device 0 under
        // the block layout — a static placement can spread whole experts,
        // so it fixes the persistent case; when the hot *set* moves every
        // batch (rotation below), the stale placement stops helping while
        // LLEP keeps adapting. (A single dominant expert is indivisible
        // under any placement — only LLEP's token-level split handles it.)
        let sc = Scenario::concentrated(0.6, 4);
        let stats = sc.generate_loads(&model, 8, 16_384, &mut rng).expert_loads();
        let placement = Placement::balanced_lpt(&stats, 8);
        // adversarial drift: each batch, the hot set is 4 experts the
        // static placement happened to COLOCATE on one device
        let hot_set_on = |d: usize| -> Vec<usize> {
            (0..model.num_experts).filter(|&e| placement.device_of(e) == d).take(4).collect()
        };
        let make_hot = |hot: &[usize], rng: &mut Rng| {
            let n = model.num_experts;
            let mut lm = Scenario::balanced().generate_loads(&model, 8, 16_384, rng);
            for row in lm.counts.iter_mut() {
                let total: u64 = row.iter().sum();
                let hot_share = (total as f64 * 0.6 / 4.0) as u64;
                let cold = (total - hot_share * 4) / (n as u64 - 4);
                for (e, c) in row.iter_mut().enumerate() {
                    *c = if hot.contains(&e) { hot_share } else { cold };
                }
                // keep K-multiple totals
                let new_total: u64 = row.iter().sum();
                let rem = new_total % model.top_k as u64;
                if rem != 0 {
                    row[0] += model.top_k as u64 - rem;
                }
            }
            lm
        };
        for (regime, moving) in [("persistent hot set", false), ("moving hot set", true)] {
            let (mut ep, mut placed, mut llep) = (0.0, 0.0, 0.0);
            for batch in 0..6 {
                let lm = if moving {
                    make_hot(&hot_set_on(batch % 8), &mut rng)
                } else {
                    sc.generate_loads(&model, 8, 16_384, &mut rng)
                };
                ep += engine.run_step_loads(&lm, &PlannerKind::StandardEp).latency_s;
                let lm_placed = placement.permute_matrix(&lm);
                placed += engine.run_step_loads(&lm_placed, &PlannerKind::StandardEp).latency_s;
                llep += engine.run_step_loads(&lm, &PlannerKind::llep_default()).latency_s;
            }
            t.row(vec![
                regime.into(),
                format!("{ep:.4}s"),
                format!("{placed:.4}s"),
                format!("{llep:.4}s"),
            ]);
        }
        println!("Ablation — static LPT placement vs per-step LLEP\n{}", t.render());
    }

    // --- Ablation: weight-transfer/compute overlap (paper §4) -------------
    let engine_ov = engine.clone().with_overlap();
    let mut rng = Rng::new(5);
    let lm = Scenario::concentrated(0.95, 1).generate_loads(&model, 8, 32_768, &mut rng);
    let base = engine.run_step_loads(&lm, &PlannerKind::llep_default());
    let ov = engine_ov.run_step_loads(&lm, &PlannerKind::llep_default());
    println!("\nAblation — weight-transfer overlap (95% into 1):");
    println!(
        "  LLEP base {:.4}s -> overlapped {:.4}s ({:.1}% faster; weights_s {:.1} µs hidden)",
        base.latency_s,
        ov.latency_s,
        (1.0 - ov.latency_s / base.latency_s) * 100.0,
        base.phases.weights_s * 1e6
    );
}
