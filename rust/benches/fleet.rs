//! Bench: fleet-level serving — router policies under heterogeneous
//! replicas, and the whole-replica failure drill.
//!
//! Three measurements:
//!
//! 1. **Router shoot-out** — 3 replicas, one at quarter speed, under a
//!    bursty workload: p99 TTFT / goodput per routing policy. Asserts
//!    the pinned contract that queue-aware routing beats round-robin on
//!    p99 TTFT (same contract as `rust/tests/fleet.rs`).
//! 2. **Failure drill** — a whole-replica failure mid-run with
//!    drain-and-reroute: requeue counts, goodput retention, and the
//!    exact summed ledger.
//! 3. **Simulator wall time** — host-side cost of one fleet run (the
//!    discrete-event loop itself must stay cheap enough for sweeps).
//!
//! Run: `cargo bench --bench fleet` (add `--quick` to shrink).

use llep::fleet::{FleetFaultPlan, FleetSim, ReplicaConfig, RouterPolicy, Workload};
use llep::metrics::{fleet_replica_table, format_secs, Table};
use llep::prelude::*;
use llep::util::benchkit::{bb, quick_requested, Bencher};
use llep::util::rng::Rng;

fn main() {
    let quick = quick_requested();
    let engine = Engine::modeled(
        ModelConfig::preset(ModelPreset::Fig1Layer),
        SystemConfig::preset(SystemPreset::H200x8),
    );
    let scenario = Scenario::concentrated(0.8, 4);
    let n_req = if quick { 32 } else { 96 };
    let wl = Workload::parse(&format!(
        "bursty:n={n_req},ia=0.00005,burst=8,every=16,prompt=512-2048,decode=2-8"
    ))
    .unwrap();

    // ---- 1. router shoot-out: one quarter-speed replica ------------------
    let replicas = || {
        vec![
            ReplicaConfig::default(),
            ReplicaConfig::default(),
            ReplicaConfig::default().with_speed(0.25),
        ]
    };
    let fleet = |router| {
        FleetSim::new(engine.clone(), scenario.clone(), replicas(), 16_384)
            .with_workload(wl.clone())
            .with_router(router)
            .try_run(7)
            .expect("fleet run")
    };
    let policies = [RouterPolicy::RoundRobin, RouterPolicy::LeastQueue, RouterPolicy::Pressure];
    let runs: Vec<_> = policies.iter().map(|&p| fleet(p)).collect();
    let mut t = Table::new(&[
        "router",
        "p99 TTFT",
        "p99 latency",
        "goodput",
        "makespan",
        "slow-replica share",
    ]);
    for r in &runs {
        assert_eq!(r.completed, r.requests, "{}: lost requests", r.router);
        assert!(r.tokens.is_exact(), "{}: {:?}", r.router, r.tokens);
        t.row(vec![
            r.router.clone(),
            format_secs(r.ttft.p99),
            format_secs(r.request_latency.p99),
            format!("{:.0} tok/s", r.goodput_tps),
            format_secs(r.makespan_s),
            format!("{}/{}", r.replicas[2].routed, r.requests),
        ]);
    }
    println!(
        "Router shoot-out: 3 replicas (one at 0.25x), {} | {n_req} requests\n",
        wl.label()
    );
    println!("{}", t.render());
    let (rr, lq) = (&runs[0], &runs[1]);
    assert!(
        lq.ttft.p99 < rr.ttft.p99,
        "contract: least-queue p99 TTFT {} must beat round-robin {}",
        lq.ttft.p99,
        rr.ttft.p99
    );

    // ---- 2. whole-replica failure drill ----------------------------------
    let arrivals = wl.generate(&mut Rng::new(7));
    let kill_at = arrivals[n_req / 3].arrival_s;
    let faults = FleetFaultPlan::parse(&format!(
        "fail:r=1,at={kill_at};recover:r=1,at={}",
        kill_at * 3.0
    ))
    .unwrap();
    let drill = FleetSim::new(engine.clone(), scenario.clone(), replicas(), 16_384)
        .with_workload(wl.clone())
        .with_faults(faults)
        .try_run(7)
        .expect("fleet must survive a whole-replica failure");
    assert_eq!(drill.completed, drill.requests);
    assert!(drill.tokens.is_exact(), "summed ledger: {:?}", drill.tokens);
    assert!(drill.max_requeues <= 1, "one failure: at most one requeue per request");
    println!(
        "Failure drill: replica 1 dies at {} and rejoins at {}\n",
        format_secs(kill_at),
        format_secs(kill_at * 3.0)
    );
    println!("{}", fleet_replica_table(&drill).render());
    println!(
        "{} requeued request(s) (max {} per request), goodput {:.0} tok/s vs {:.0} healthy",
        drill.requeued_requests, drill.max_requeues, drill.goodput_tps, lq.goodput_tps
    );

    // ---- 3. simulator wall time ------------------------------------------
    let mut b = if quick { Bencher::quick() } else { Bencher::new() };
    let sim = FleetSim::new(engine, scenario, replicas(), 16_384)
        .with_workload(wl)
        .with_router(RouterPolicy::LeastQueue);
    let wall = b.bench("fleet/least-queue/run", || bb(sim.try_run(7).unwrap().completed));
    println!(
        "\nfleet run wall time {} for {n_req} requests x 3 replicas",
        format_secs(wall.mean_s())
    );
}
