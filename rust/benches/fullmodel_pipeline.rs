//! Bench: the multi-layer pipelined engine — full-model steps (one LLEP
//! plan per MoE layer, planning overlapped with execution and fanned out
//! across threads) vs standard EP on a depth-varying imbalance profile,
//! plus the wall cost of `run_model` itself against a serial
//! `run_step_loads` loop over the same layers.
//!
//! Run: `cargo bench --bench fullmodel_pipeline` (add `--quick` to shrink).

use llep::metrics::{format_bytes, format_secs, model_report_to_json, Table};
use llep::prelude::*;
use llep::util::benchkit::{bb, quick_requested, Bencher};

fn main() {
    let quick = quick_requested();
    let model = ModelConfig::preset(ModelPreset::GptOss120b); // 36 MoE layers
    let engine = Engine::modeled(model.clone(), SystemConfig::preset(SystemPreset::H200x8));
    let tokens = if quick { 8192 } else { 32_768 };

    // Depth-varying imbalance: a different dominant expert per layer.
    let profile = DepthProfile::varying(&model, 0.45, 0.25);
    let mut rng = Rng::new(1);
    let lms = profile.generate_loads(&model, 8, tokens, &mut rng);

    let ep = engine.run_model(&lms, &PlannerKind::StandardEp).unwrap();
    let ll = engine.run_model(&lms, &PlannerKind::llep_default()).unwrap();

    let mut t = Table::new(&[
        "planner", "model latency", "serial", "overlap saved", "peak mem", "fallback layers",
    ]);
    for r in [&ep, &ll] {
        t.row(vec![
            r.planner.clone(),
            format_secs(r.latency_s),
            format_secs(r.serial_latency_s),
            format_secs(r.overlap_saved_s),
            format_bytes(r.max_peak_bytes()),
            format!("{}/{}", r.fallback_layers, r.num_layers()),
        ]);
    }
    println!(
        "Full-model step — gpt-oss-120b, {} MoE layers, P=8, {tokens} tokens/device, \
         depth-varying hotspots\n",
        model.num_moe_layers()
    );
    println!("{}", t.render());
    println!(
        "multi-layer LLEP speedup over EP: {:.2}x\n",
        ep.latency_s / ll.latency_s
    );
    println!("machine-readable (LLEP): {}\n", model_report_to_json(&ll).to_string());

    // Wall cost of the simulator itself: parallel-planned run_model vs a
    // serial per-layer loop over the identical loads.
    let mut b = if quick { Bencher::quick() } else { Bencher::new() };
    b.bench("run_model/llep/36-layers", || {
        bb(engine.run_model(&lms, &PlannerKind::llep_default()))
    });
    b.bench("run_model/ep/36-layers", || bb(engine.run_model(&lms, &PlannerKind::StandardEp)));
    b.bench("serial_loop/llep/36-layers", || {
        let mut acc = 0.0f64;
        for lm in &lms {
            acc += engine.run_step_loads(lm, &PlannerKind::llep_default()).latency_s;
        }
        bb(acc)
    });
    b.bench("run_step/llep/1-layer", || {
        bb(engine.run_step_loads(&lms[0], &PlannerKind::llep_default()))
    });
}
