//! Bench: Fig. 8 — grouped-GEMM: same total FLOPs split across more
//! experts takes longer. Three columns:
//!
//! * the Eq.-3 model at H200 scale (the paper's cuBLAS-loop regime),
//! * *real measured* native rust GEMMs — which turn out FLAT, because a
//!   portable CPU kernel has no launch overhead: this column validates
//!   that the work itself is constant,
//! * *real measured* PJRT executions of the Pallas expert-FFN artifact,
//!   where per-call dispatch overhead (literal creation, buffer setup,
//!   executable invocation) is real — reproducing the paper's shape on
//!   this machine's actual accelerator-style execution path.
//!
//! Run: `cargo bench --bench fig8_gemm` (add `--quick` to shrink;
//! the PJRT column requires `make artifacts`).

use llep::costmodel::GemmCostModel;
#[cfg(feature = "pjrt")]
use llep::exec::ExpertCompute;
use llep::metrics::{format_secs, Table};
#[cfg(feature = "pjrt")]
use llep::moe::MoeLayer;
use llep::prelude::*;
use llep::tensor::{matmul, Mat};
use llep::util::benchkit::{bb, quick_requested, Bencher};

fn main() {
    let quick = quick_requested();
    let sys = SystemConfig::preset(SystemPreset::H200x8);
    let gemm = GemmCostModel::from_system(&sys);
    let paper_model = ModelConfig {
        d_model: 8192,
        d_ff: 8192,
        swiglu: false,
        ..ModelConfig::preset(ModelPreset::Fig1Layer)
    };

    // Native measurement: total 2048 x 64 x 64 GEMM work split n ways.
    let d = 64usize;
    let total_tokens = if quick { 512 } else { 2048 };
    let mut rng = Rng::new(1);
    let w = Mat::randn(d, d, 0.02, &mut rng);

    // PJRT measurement: tiny-geometry expert FFN artifact, bucketed.
    #[cfg(feature = "pjrt")]
    let pjrt_setup = llep::runtime::Runtime::open(&llep::runtime::Runtime::default_dir())
        .ok()
        .map(|rt| {
            let model = {
                let mut m = ModelConfig::preset(ModelPreset::Tiny);
                m.d_model = 32;
                m.d_ff = 64;
                m
            };
            let layer = MoeLayer::random(&model, &mut Rng::new(2));
            (rt, layer)
        });

    let mut bench = if quick { Bencher::quick() } else { Bencher::new() };
    let mut table = Table::new(&[
        "experts",
        "modeled (H200, 64K tok)",
        "native CPU (no launch cost)",
        "PJRT artifact (real dispatch)",
    ]);
    for &n in &[1usize, 2, 4, 8, 16, 32, 64, 128] {
        let per = vec![65_536u64 / n as u64; n];
        let modeled = gemm.device_compute_time(&per, &paper_model);

        let x = Mat::randn(total_tokens / n, d, 0.1, &mut rng);
        let native = bench.bench(&format!("grouped_gemm/native/n={n}"), || {
            for _ in 0..n {
                bb(matmul(&x, &w));
            }
        });

        #[cfg(not(feature = "pjrt"))]
        let pjrt_cell = "requires --features pjrt".to_string();
        #[cfg(feature = "pjrt")]
        let pjrt_cell = match &pjrt_setup {
            None => "run `make artifacts`".to_string(),
            Some((rt, layer)) => {
                let pjrt = llep::runtime::PjrtCompute::new(rt).expect("buckets");
                let rows = (1024 / n).max(1);
                let xp = Mat::randn(rows, layer.model.d_model, 0.1, &mut Rng::new(3));
                let r = bench.bench(&format!("grouped_gemm/pjrt/n={n}"), || {
                    for _ in 0..n {
                        bb(pjrt.ffn(&xp, &layer.experts[0]));
                    }
                });
                format_secs(r.mean_s())
            }
        };
        table.row(vec![
            n.to_string(),
            format_secs(modeled),
            format_secs(native.mean_s()),
            pjrt_cell,
        ]);
    }
    println!("\nFig 8 — execution time vs number of experts at fixed total FLOPs\n");
    println!("{}", table.render());
    println!(
        "(modeled + PJRT columns must increase with expert count — the paper's\n\
         launch-overhead effect; the native column is flat because a portable\n\
         CPU GEMM has no per-call dispatch cost, isolating the effect's cause)"
    );
}
