//! Bench: successive-halving vs full-grid autotuning.
//!
//! Runs both strategies over the smoke search space on the paper's
//! H200x8 profile for (a) the stationary skewed scenario (90% of load
//! into one expert) and (b) the drifting-hotspot scenario, reporting
//! budget units priced, the best spec found, and the gap to the
//! full-grid optimum. On the stationary scenario per-batch loads are
//! identical, so halving's rung rankings are provably stable and the
//! gap must be exactly zero (asserted); on the drifting scenario
//! low-fidelity rungs see fewer hotspot draws and the reported gap can
//! be non-zero.
//!
//! Run: `cargo bench --bench tuner_convergence` (add `--quick` to
//! shrink the per-batch token count).

use llep::config::{ModelConfig, ModelPreset};
use llep::metrics::{format_secs, Table};
use llep::planner::Registry;
use llep::prelude::*;
use llep::tune::Mode;
use llep::util::benchkit::quick_requested;

fn main() {
    let quick = quick_requested();
    let tokens = if quick { 2048 } else { 8192 };
    let scenarios = [
        ("skewed 90%->1", Scenario::concentrated(0.9, 1)),
        ("drift", Scenario::drifting(11, 0.5, 0.25)),
    ];

    let engine = || {
        Engine::modeled(
            ModelConfig::preset(ModelPreset::Fig1Layer),
            HardwareProfile::builtin("h200x8").unwrap().system,
        )
        .with_plan_cost(PlanCostModel::default())
    };

    let space = SearchSpace::from_registry(&Registry::builtin(), SpaceBudget::Smoke).unwrap();
    let spec_count = space.len();
    let mut t = Table::new(&[
        "scenario", "strategy", "units priced", "best spec", "best latency", "gap vs grid",
    ]);
    for (name, scenario) in scenarios {
        let grid_tuner = Tuner::new(engine(), scenario.clone(), Mode::Step, 0)
            .with_tokens(tokens)
            .with_full_budget(8);
        let grid = grid_tuner.run(&space, Strategy::Grid).unwrap();
        let halving_tuner = Tuner::new(engine(), scenario.clone(), Mode::Step, 0)
            .with_tokens(tokens)
            .with_full_budget(8);
        let halving = halving_tuner.run(&space, Strategy::Halving { eta: 2 }).unwrap();

        let gb = grid.recommended.as_ref().expect("grid recommends");
        let hb = halving.recommended.as_ref().expect("halving recommends");
        let gap = (hb.metrics.latency_s - gb.metrics.latency_s) / gb.metrics.latency_s;
        for (is_grid, out, best) in [(true, &grid, gb), (false, &halving, hb)] {
            t.row(vec![
                name.to_string(),
                out.strategy.clone(),
                out.priced_units.to_string(),
                best.spec.clone(),
                format_secs(best.metrics.latency_s),
                if is_grid { "-".to_string() } else { format!("{:+.2}%", gap * 100.0) },
            ]);
        }

        assert!(
            halving.priced_units < grid.priced_units,
            "{name}: halving must price strictly fewer units ({} vs {})",
            halving.priced_units,
            grid.priced_units
        );
        if name.starts_with("skewed") {
            assert!(
                gap.abs() < 1e-12,
                "{name}: stationary loads make halving exact, got gap {gap}"
            );
        }
    }
    println!("Tuner convergence — smoke space ({spec_count} specs), full budget 8 steps, P=8\n");
    println!("{}", t.render());
    println!(
        "halving prunes with cached low-fidelity rungs (trial cache keyed by spec/scenario/\
         system/budget), so rung re-ranks never re-price already-evaluated points."
    );
}
