//! Bench: Fig. 4 — EP vs LLEP across the three MoE architectures the
//! paper evaluates (gpt-oss-120b, DeepSeek-V3, Kimi-K2) plus Fig. 1c
//! full-model throughput.
//!
//! Run: `cargo bench --bench fig4_archs` (add `--quick` to shrink).

use llep::harness::{compare, fullmodel, paper_scenarios};
use llep::metrics::{format_bytes, Table};
use llep::prelude::*;
use llep::util::benchkit::quick_requested;

fn main() {
    let quick = quick_requested();
    let mut table = Table::new(&["model", "scenario", "speedup", "EP peak", "LLEP peak", "EP OOM"]);
    let configs: &[(ModelPreset, usize)] = &[
        (ModelPreset::GptOss120b, 32_768),
        (ModelPreset::DeepSeekV3, 16_384),
        (ModelPreset::KimiK2, 16_384),
    ];
    for &(preset, tokens) in configs {
        let model = ModelConfig::preset(preset);
        let engine = Engine::modeled(model.clone(), SystemConfig::preset(SystemPreset::H200x8));
        let llep = LlepConfig::default(); // paper §5.1: lambda=1.3 alpha=1 m=1024
        let tokens = if quick { tokens / 4 } else { tokens };
        for sc in paper_scenarios(model.num_experts) {
            let (speedup, ep, ll) = compare(&engine, &sc, tokens, &llep, 4);
            table.row(vec![
                model.name.clone(),
                sc.label(),
                format!("{speedup:.2}x"),
                format_bytes(ep.max_peak_bytes()),
                format_bytes(ll.max_peak_bytes()),
                if ep.oom { "OOM".into() } else { "-".into() },
            ]);
        }
    }
    println!("Fig 4 — three architectures, P=8 H200 (B per paper §5.1)\n");
    println!("{}", table.render());

    println!("Fig 1c — full-model throughput (in-the-wild drifting routing)\n");
    let mut t = Table::new(&["model", "P", "EP tok/s", "LLEP tok/s", "speedup"]);
    for (preset, devices) in [
        (ModelPreset::GptOss20b, 4),
        (ModelPreset::GptOss20b, 8),
        (ModelPreset::GptOss120b, 8),
    ] {
        let row = fullmodel::throughput_row(preset, devices, if quick { 8192 } else { 32_768 }, 7);
        t.row(vec![
            row.model.clone(),
            devices.to_string(),
            format!("{:.0}", row.ep_tps),
            format!("{:.0}", row.llep_tps),
            format!("{:.2}x", row.speedup()),
        ]);
    }
    println!("{}", t.render());
}
