//! Bench: persistent re-layout vs per-step spill vs the hybrid.
//!
//! A drifting hotspot (the hot expert set rotates across devices every
//! few steps) priced under three strategies:
//!
//! 1. **Per-step spill** — bare LLEP: rebalances every step but re-ships
//!    the same expert weights as spill transfers on every step of every
//!    regime.
//! 2. **Pure re-layout** — `placed(ep)`: the layout migrates hot experts
//!    apart (amortized against the horizon), but between migrations the
//!    static inner planner eats the imbalance.
//! 3. **Hybrid** — `placed(llep)`: the layout absorbs the persistent
//!    pattern while LLEP spills the residual with *current* loads during
//!    adaptation.
//!
//! A tight migration budget (1 move/round) stretches the adaptation
//! window so the strategies actually separate. A microbench at the end
//! prices the decorator's planning overhead.
//!
//! Run: `cargo bench --bench placement` (add `--quick` to shrink).

use llep::metrics::{format_bytes, format_secs, Table};
use llep::planner::Registry;
use llep::prelude::*;
use llep::routing::LoadMatrix;
use llep::util::benchkit::{bb, quick_requested, Bencher};

const DEVICES: usize = 4;
const EXPERTS: usize = 16;

fn lm_from_loads(loads: &[u64], devices: usize) -> LoadMatrix {
    let mut counts = vec![vec![0u64; loads.len()]; devices];
    counts[0] = loads.to_vec();
    LoadMatrix { counts, top_k: 1 }
}

fn drifting_hotspot(steps: usize, phase_len: usize, hot: u64) -> Vec<Vec<u64>> {
    (0..steps)
        .map(|t| {
            let lo = ((t / phase_len) % DEVICES) * 4;
            (0..EXPERTS).map(|e| if e >= lo && e < lo + 4 { hot } else { 100 }).collect()
        })
        .collect()
}

fn main() {
    let quick = quick_requested();
    let mut model = ModelConfig::preset(ModelPreset::Fig1Layer);
    model.num_experts = EXPERTS;
    let engine =
        Engine::modeled(model, SystemConfig::preset(SystemPreset::H200x8).with_devices(DEVICES))
            .with_plan_cost(PlanCostModel::default());

    let steps = if quick { 16 } else { 48 };
    let seq = drifting_hotspot(steps, if quick { 4 } else { 8 }, 16_000);
    let reg = Registry::builtin();

    let strategies = [
        ("per-step spill", "llep"),
        ("pure re-layout", "placed(ep):budget=1"),
        ("hybrid", "placed(llep):budget=1"),
    ];
    let mut t =
        Table::new(&["strategy", "spec", "mean step", "weight bytes", "migrations", "re-layouts"]);
    let mut results = Vec::new();
    for (label, spec) in strategies {
        let planner = reg.parse(spec).unwrap_or_else(|e| panic!("{spec}: {e}"));
        let mut bytes = 0u64;
        let mut lat = 0.0;
        let mut migrations = 0u64;
        let mut relayouts = 0u64;
        for loads in &seq {
            let r = engine.run_step_loads(&lm_from_loads(loads, DEVICES), &*planner);
            assert!(!r.oom && !r.stranded, "{spec}: healthy drifting run");
            bytes += r.bytes_weights + r.placement.migration_bytes;
            lat += r.latency_s;
            migrations += r.placement.migrations;
            relayouts += r.placement.relayouts;
        }
        let mean = lat / seq.len() as f64;
        t.row(vec![
            label.into(),
            spec.into(),
            format_secs(mean),
            format_bytes(bytes),
            migrations.to_string(),
            relayouts.to_string(),
        ]);
        results.push((label, bytes, mean));
    }
    println!(
        "Drifting hotspot: 4 colliding hot experts rotate across {DEVICES} devices, {steps} steps\n"
    );
    println!("{}", t.render());

    let spill = &results[0];
    let relayout = &results[1];
    let hybrid = &results[2];
    assert!(
        hybrid.1 < spill.1,
        "hybrid must move fewer weight bytes than per-step spill: {} vs {}",
        hybrid.1,
        spill.1
    );
    assert!(
        hybrid.2 <= relayout.2,
        "hybrid must not price worse than pure re-layout: {} vs {}",
        hybrid.2,
        relayout.2
    );
    println!(
        "hybrid ships {} vs per-step spill {} ({:.1}% of the bytes), mean step {} vs pure \
         re-layout {}\n",
        format_bytes(hybrid.1),
        format_bytes(spill.1),
        100.0 * hybrid.1 as f64 / spill.1.max(1) as f64,
        format_secs(hybrid.2),
        format_secs(relayout.2),
    );

    // ---- decorator planning overhead -------------------------------------
    let loads = &seq[0];
    let bare = reg.parse("llep").unwrap();
    let placed = reg.parse("placed(llep)").unwrap();
    // Settle the layout first so the microbench prices the steady state.
    for _ in 0..8 {
        let plan = placed.plan(DEVICES, loads, None);
        llep::planner::recycle_plan(plan);
    }
    let mut b = if quick { Bencher::quick() } else { Bencher::new() };
    let flat = b.bench("plan/llep/N=16", || bb(bare.plan(DEVICES, loads, None)));
    let wrapped = b.bench("plan/placed(llep)/settled/N=16", || {
        bb(placed.plan(DEVICES, loads, None))
    });
    println!(
        "settled placed(llep) plan {} vs bare llep {} ({:.2}x)",
        format_secs(wrapped.mean_s()),
        format_secs(flat.mean_s()),
        wrapped.mean_ns / flat.mean_ns.max(1.0)
    );
}
