//! Bench: chaos-aware LLEP vs static EP on a degraded pool.
//!
//! Three measurements:
//!
//! 1. **Straggler step** — the acceptance scenario: a single 4x
//!    straggler on an 8-device pool under concentrated routing. Prices
//!    one full-model step per planner and asserts the >= 2x LLEP
//!    advantage (the same contract `rust/tests/chaos.rs` locks in).
//! 2. **Pool-aware planning microbench** — wall time of the speed-aware
//!    spill path vs the homogeneous planner (the chaos layer must not
//!    make planning meaningfully slower).
//! 3. **Failure serve** — a serve burst with a permanent failure
//!    mid-run: chaos-aware LLEP recovers (requeue + elastic replan, the
//!    ledger stays exact) while static EP is unrecoverable.
//!
//! Run: `cargo bench --bench degraded_pool` (add `--quick` to shrink).

use llep::chaos::FaultPlan;
use llep::coordinator::{Request, ServeSim};
use llep::metrics::{format_chaos, format_secs, Table};
use llep::prelude::*;
use llep::util::benchkit::{bb, quick_requested, Bencher};

fn main() {
    let quick = quick_requested();
    let base = Engine::modeled(
        ModelConfig::preset(ModelPreset::Fig1Layer),
        SystemConfig::preset(SystemPreset::H200x8),
    );
    let faults = FaultPlan::parse("slow:dev=0,x=4").unwrap();
    let engine = base.for_pool(faults.state_at(0, &base.pool));
    let scenario = Scenario::concentrated(0.9, 1);

    // ---- 1. one model step under the 4x straggler ------------------------
    let tokens = if quick { 8192 } else { 16_384 };
    let profile = DepthProfile::uniform(scenario.clone(), 1);
    let mut rng = Rng::new(1);
    let lms = profile.generate_loads(&engine.model, 8, tokens, &mut rng);
    let ep = engine.run_model(&lms, &PlannerKind::StandardEp).unwrap();
    let ll = engine.run_model(&lms, &PlannerKind::llep_default()).unwrap();
    let speedup = ep.latency_s / ll.latency_s;
    let mut t = Table::new(&["planner", "step latency", "compute span", "speedup"]);
    for r in [&ep, &ll] {
        t.row(vec![
            r.planner.clone(),
            format_secs(r.latency_s),
            format_secs(r.layers[0].report.phases.compute_s),
            format!("{:.2}x", ep.latency_s / r.latency_s),
        ]);
    }
    println!("Single 4x straggler, P=8, {} | {tokens} tokens/device\n", scenario.label());
    println!("{}", t.render());
    assert!(
        speedup >= 2.0,
        "acceptance: speed-aware LLEP must be >= 2x faster under the straggler, got {speedup:.2}x"
    );

    // ---- 2. pool-aware planning wall time --------------------------------
    let loads = lms[0].expert_loads();
    let llep = PlannerKind::llep_default();
    let mut b = if quick { Bencher::quick() } else { Bencher::new() };
    let flat = b.bench("plan/llep/healthy/N=128", || bb(llep.plan(8, &loads, Some(&base.topo))));
    let aware = b.bench("plan/llep/straggler-pool/N=128", || {
        bb(llep.plan_with_pool(8, &loads, &loads, Some(&engine.topo), Some(&engine.pool)))
    });
    println!(
        "\npool-aware planning {} vs homogeneous {} ({:.2}x)\n",
        format_secs(aware.mean_s()),
        format_secs(flat.mean_s()),
        aware.mean_ns / flat.mean_ns.max(1.0)
    );

    // ---- 3. permanent failure mid-serve ----------------------------------
    let n_req = if quick { 8 } else { 16 };
    let reqs: Vec<Request> =
        (0..n_req).map(|id| Request { id, arrival_s: 0.0, tokens: 30_000 }).collect();
    let fail = FaultPlan::parse("fail:dev=1,at=2").unwrap();
    let serve = |planner: PlannerKind| {
        ServeSim::with_planner(base.clone(), planner.boxed(), scenario.clone(), 8192)
            .with_faults(fail.clone())
            .try_run(&reqs, &mut Rng::new(7))
    };
    let ep_run = serve(PlannerKind::StandardEp);
    let ll_run = serve(PlannerKind::llep_default()).expect("chaos-aware LLEP must recover");
    assert!(ep_run.is_err(), "static EP cannot survive a permanent failure");
    assert!(ll_run.tokens.is_exact(), "ledger conservation: {:?}", ll_run.tokens);
    let mut t = Table::new(&["planner", "outcome", "makespan", "p99 latency", "chaos"]);
    t.row(vec![
        "EP".into(),
        "unrecoverable".into(),
        "-".into(),
        "-".into(),
        "-".into(),
    ]);
    t.row(vec![
        ll_run.planner.clone(),
        "recovered".into(),
        format_secs(ll_run.makespan_s),
        format_secs(ll_run.request_latency.p99),
        format_chaos(&ll_run.chaos),
    ]);
    println!("Permanent failure at step 2 (fail:dev=1,at=2), {n_req} requests\n");
    println!("{}", t.render());
    println!(
        "LLEP recovered in <= {} aborted attempt(s), {} tokens requeued, {} wasted",
        ll_run.chaos.max_recovery_steps,
        ll_run.chaos.requeued_tokens,
        format_secs(ll_run.chaos.wasted_s)
    );
}
