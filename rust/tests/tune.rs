//! Integration tests for the `tune/` autotuner subsystem: search-space
//! synthesis, the bit-identical-trials contract (property-tested),
//! successive halving vs full grid, Pareto invariants, and hardware
//! profiles.

use llep::config::{LlepConfig, ModelConfig, ModelPreset, SystemConfig, SystemPreset};
use llep::exec::{Engine, PlanCostModel};
use llep::planner::{CachedPlanner, Llep};
use llep::routing::{LoadMatrix, Scenario};
use llep::tune::{
    dominates, pareto_front, HardwareProfile, Mode, SearchSpace, SpaceBudget, Strategy, Trial,
    TrialMetrics, Tuner,
};
use llep::util::prop::{assert_property, no_shrink};
use llep::util::rng::Rng;

fn paper_engine() -> Engine {
    Engine::modeled(
        ModelConfig::preset(ModelPreset::Fig1Layer),
        SystemConfig::preset(SystemPreset::H200x8),
    )
}

fn small_tuner(scenario: Scenario, mode: Mode, seed: u64) -> Tuner {
    let engine = Engine::modeled(
        ModelConfig::preset(ModelPreset::Tiny),
        SystemConfig::preset(SystemPreset::CpuSim4),
    );
    Tuner::new(engine, scenario, mode, seed).with_tokens(512).with_full_budget(4)
}

#[test]
fn smoke_space_round_trips_through_the_registry() {
    let tuner = small_tuner(Scenario::concentrated(0.9, 1), Mode::Step, 0);
    let space = SearchSpace::from_registry(&tuner.registry, SpaceBudget::Smoke).unwrap();
    assert!(!space.is_empty());
    for spec in &space.specs {
        let p = tuner.registry.parse(spec).unwrap();
        let canon = p.spec();
        let p2 = tuner.registry.parse(&canon).unwrap();
        assert_eq!(p2.spec(), canon, "synthesized spec {spec} reaches a fixed point");
    }
}

#[test]
fn recommended_spec_reproduces_trial_metrics_bit_identically() {
    // The acceptance contract: whatever the tuner recommends, passing
    // the spec back under the same (profile, scenario, seed) re-prices
    // to the exact reported bits. Property-tested over seeds, modes and
    // specs (including the stateful cached decorator).
    let specs = [
        "ep",
        "llep:alpha=1.25,m=256,lambda=1.1",
        "eplb:r=4",
        "lpt:min=256",
        "chunked:c=2048",
        "cached(llep):drift=0.15,every=2",
    ];
    assert_property(
        "tune trials are bit-reproducible",
        0xB17,
        12,
        |rng: &mut Rng| (rng.next_u64() % 1000, rng.index(specs.len()), rng.index(2)),
        |&(seed, spec_idx, mode_idx): &(u64, usize, usize)| {
            let mode = if mode_idx == 0 { Mode::Step } else { Mode::Serve };
            let spec = specs[spec_idx];
            let tuner = small_tuner(Scenario::concentrated(0.9, 1), mode, seed);
            let trial = tuner.evaluate(spec, 3)?;
            // verify() recomputes from scratch, bypassing the cache.
            if !tuner.verify(&trial)? {
                return Err(format!("{spec} did not re-price bit-identically ({mode:?})"));
            }
            // A second, completely fresh tuner agrees too.
            let other = small_tuner(Scenario::concentrated(0.9, 1), mode, seed);
            let again = other.evaluate(spec, 3)?;
            if again.metrics.latency_s.to_bits() != trial.metrics.latency_s.to_bits()
                || again.metrics.peak_bytes != trial.metrics.peak_bytes
            {
                return Err(format!("{spec}: fresh tuner disagreed ({mode:?})"));
            }
            Ok(())
        },
        no_shrink,
    );
}

#[test]
fn repair_tier_pricing_is_bit_reproducible_and_scales_with_peels() {
    // The repair-aware plan-cost contract behind bit-identical trials:
    // a repaired step charges T_plan = hit_s + peeled × repair_s (the
    // tier's actual O(changed work) shape, not a flat constant). Two
    // fresh runs over the same drift sequence must reproduce every
    // step's T_plan bit-identically, and every repaired step must land
    // an integral number of peels above a hit, strictly below fresh.
    let cost = PlanCostModel::default();
    let e = paper_engine().with_plan_cost(cost);

    // A hot head leaking mass to a cold expert: ~3% of total per step,
    // so successive lookups sit inside the repair band (above the
    // retarget threshold, below the 0.2 ceiling).
    let mut base = vec![500u64; 128];
    for l in base.iter_mut().take(4) {
        *l = 60_000;
    }
    let total: u64 = base.iter().sum();
    let steps: Vec<Vec<u64>> = (0..3)
        .map(|k| {
            let mut v = base.clone();
            let moved = (total / 33) * k;
            v[0] -= moved;
            v[100] += moved;
            v
        })
        .collect();

    let run = || -> Vec<(u64, u64)> {
        let cached = CachedPlanner::new(Box::new(Llep::new(LlepConfig::default())))
            .with_repair_ceiling(0.2);
        steps
            .iter()
            .map(|loads| {
                let mut counts = vec![vec![0u64; loads.len()]; 8];
                counts[0] = loads.clone();
                let lm = LoadMatrix { counts, top_k: 1 };
                let r = e.run_step_loads(&lm, &cached);
                (r.phases.plan_s.to_bits(), r.cache.repairs)
            })
            .collect()
    };

    let a = run();
    let b = run();
    assert_eq!(a, b, "repair-aware T_plan must be bit-reproducible");
    assert!(a.iter().any(|&(_, reps)| reps == 1), "the drift must exercise the repair tier");
    for &(bits, reps) in &a {
        if reps == 1 {
            let plan_s = f64::from_bits(bits);
            assert!(plan_s < cost.fresh_s, "a repair prices below a fresh plan: {plan_s}");
            let peels = (plan_s - cost.hit_s) / cost.repair_s;
            assert!(
                peels >= 1.0 - 1e-9 && (peels - peels.round()).abs() < 1e-6,
                "T_plan = hit_s + k·repair_s for integral k >= 1, got {peels}"
            );
        }
    }
}

#[test]
fn halving_finds_the_grid_optimum_with_strictly_fewer_trials() {
    // Acceptance: on the smoke grid, successive halving lands within 5%
    // of the full-grid optimum while pricing strictly fewer budget
    // units. (On a stationary concentrated scenario per-batch loads are
    // identical, so rung rankings are stable and the gap is exactly 0 —
    // well inside the 5% bound.)
    let scenario = Scenario::concentrated(0.9, 1);
    let grid_tuner = small_tuner(scenario.clone(), Mode::Step, 7);
    let space = SearchSpace::from_registry(&grid_tuner.registry, SpaceBudget::Smoke).unwrap();
    let grid = grid_tuner.run(&space, Strategy::Grid).unwrap();
    let halving_tuner = small_tuner(scenario, Mode::Step, 7);
    let halving = halving_tuner.run(&space, Strategy::Halving { eta: 2 }).unwrap();

    let grid_best = grid.recommended.as_ref().expect("grid finds a feasible spec");
    let halving_best = halving.recommended.as_ref().expect("halving finds a feasible spec");
    assert!(
        halving_best.metrics.latency_s <= grid_best.metrics.latency_s * 1.05,
        "halving {} ({}) vs grid optimum {} ({})",
        halving_best.metrics.latency_s,
        halving_best.spec,
        grid_best.metrics.latency_s,
        grid_best.spec
    );
    assert!(
        halving.priced_units < grid.priced_units,
        "halving must price strictly fewer units: {} vs {}",
        halving.priced_units,
        grid.priced_units
    );
    assert_eq!(halving_best.budget, grid_best.budget, "final rung runs at full fidelity");
}

#[test]
fn pareto_front_is_nondominated_and_recommendation_parses() {
    let tuner = small_tuner(Scenario::concentrated(0.8, 2), Mode::Step, 3);
    let space = SearchSpace::from_registry(&tuner.registry, SpaceBudget::Smoke).unwrap();
    let out = tuner.run(&space, Strategy::Grid).unwrap();
    assert!(!out.front.is_empty(), "non-empty Pareto front");
    for a in &out.front {
        assert!(!a.metrics.oom);
        for b in &out.front {
            assert!(
                a.spec == b.spec || !dominates(&a.metrics, &b.metrics),
                "{} dominates {} inside the front",
                a.spec,
                b.spec
            );
        }
    }
    // Every trial is covered by the front.
    for t in out.trials.iter().filter(|t| !t.metrics.oom) {
        assert!(
            out.front.iter().any(|f| f.spec == t.spec || dominates(&f.metrics, &t.metrics)
                || (f.metrics.latency_s <= t.metrics.latency_s
                    && f.metrics.peak_bytes <= t.metrics.peak_bytes)),
            "{} uncovered by the front",
            t.spec
        );
    }
    let rec = out.recommended.as_ref().unwrap();
    let planner = tuner.registry.parse(&rec.spec).unwrap();
    assert_eq!(
        tuner.registry.parse(&planner.spec()).unwrap().spec(),
        planner.spec(),
        "recommendation round-trips"
    );
}

#[test]
fn serve_mode_tunes_tpot_and_emits_a_front() {
    let tuner = small_tuner(Scenario::concentrated(0.9, 1), Mode::Serve, 5).with_full_budget(6);
    let space = SearchSpace::from_registry(&tuner.registry, SpaceBudget::Smoke).unwrap();
    let out = tuner.run(&space, Strategy::Grid).unwrap();
    assert!(!out.front.is_empty());
    let rec = out.recommended.as_ref().unwrap();
    assert!(rec.metrics.latency_s > 0.0, "p50 TPOT objective is populated");
    assert!(tuner.verify(rec).unwrap(), "serve trials reproduce bit-identically");
}

#[test]
fn tighter_memory_profile_changes_feasibility() {
    // The same workload that fits on H200 OOMs for standard EP on a
    // profile with a small HBM ceiling, so the tuner's front moves —
    // the "hardware-specific" point of the subsystem.
    let scenario = Scenario::concentrated(0.95, 1);
    let roomy = Tuner::new(paper_engine(), scenario.clone(), Mode::Step, 1).with_tokens(65_536);
    let ep_roomy = roomy.evaluate("ep", 2).unwrap();
    assert!(!ep_roomy.metrics.oom, "EP fits the H200 profile");

    let mut tight_sys = SystemConfig::preset(SystemPreset::H200x8);
    tight_sys.name = "tight".into();
    tight_sys.mem_capacity_bytes = 4 << 30;
    let tight_engine =
        Engine::modeled(ModelConfig::preset(ModelPreset::Fig1Layer), tight_sys);
    let tight = Tuner::new(tight_engine, scenario, Mode::Step, 1).with_tokens(65_536);
    let ep_tight = tight.evaluate("ep", 2).unwrap();
    assert!(ep_tight.metrics.oom, "EP blows the tight profile's ceiling");
    let llep_tight = tight.evaluate("llep", 2).unwrap();
    assert!(!llep_tight.metrics.oom, "LLEP still fits (paper Fig. 1b)");
    // And the front over {ep, llep} on the tight profile excludes EP.
    let trials = vec![ep_tight, llep_tight.clone()];
    let front = pareto_front(&trials);
    assert_eq!(front.len(), 1);
    assert_eq!(front[0].spec, "llep");
}

#[test]
fn profile_toml_drives_the_tuner() {
    let profile = HardwareProfile::from_toml(
        "[profile]\nname = \"custom\"\nbase = \"cpusim4\"\nmem_capacity_gb = 1.0\n",
    )
    .unwrap();
    assert_eq!(profile.name, "custom");
    let engine = Engine::modeled(ModelConfig::preset(ModelPreset::Tiny), profile.system)
        .with_plan_cost(PlanCostModel::default());
    let tuner = Tuner::new(engine, Scenario::concentrated(0.9, 1), Mode::Step, 0)
        .with_tokens(512)
        .with_full_budget(2);
    let trial = tuner.evaluate("llep", 2).unwrap();
    assert!(trial.metrics.latency_s > 0.0);
}

#[test]
fn front_ordering_matches_ranked_trials() {
    // The recommendation is both front[0] and the top-ranked trial.
    let tuner = small_tuner(Scenario::power_law(1.2), Mode::Step, 9);
    let space = SearchSpace::from_registry(&tuner.registry, SpaceBudget::Smoke).unwrap();
    let out = tuner.run(&space, Strategy::Grid).unwrap();
    let rec = out.recommended.as_ref().unwrap();
    assert_eq!(out.front[0].spec, rec.spec);
    assert_eq!(out.trials[0].spec, rec.spec);
    // Front latencies ascend while memory strictly descends.
    for w in out.front.windows(2) {
        assert!(w[0].metrics.latency_s <= w[1].metrics.latency_s);
        assert!(w[0].metrics.peak_bytes > w[1].metrics.peak_bytes);
    }
}

#[test]
fn synthetic_pareto_property_over_random_trials() {
    assert_property(
        "pareto front covers every feasible trial",
        0xF00D,
        60,
        |rng: &mut Rng| {
            let n = 1 + rng.index(20);
            (0..n)
                .map(|i| Trial {
                    spec: format!("s{i}"),
                    budget: 1,
                    metrics: TrialMetrics {
                        latency_s: (1 + rng.index(50)) as f64 / 10.0,
                        peak_bytes: (1 + rng.index(50)) as u64,
                        oom: rng.index(10) == 0,
                        stranded: rng.index(10) == 0,
                    },
                })
                .collect::<Vec<Trial>>()
        },
        |trials: &Vec<Trial>| {
            let front = pareto_front(trials);
            for f in &front {
                if f.metrics.oom || f.metrics.stranded {
                    return Err("infeasible point on the front".into());
                }
            }
            for (a, b) in front.iter().zip(front.iter().skip(1)) {
                if dominates(&b.metrics, &a.metrics) || dominates(&a.metrics, &b.metrics) {
                    return Err(format!("{} and {} dominate within front", a.spec, b.spec));
                }
            }
            for t in trials.iter().filter(|t| !t.metrics.oom && !t.metrics.stranded) {
                let covered = front.iter().any(|f| {
                    f.metrics.latency_s <= t.metrics.latency_s
                        && f.metrics.peak_bytes <= t.metrics.peak_bytes
                });
                if !covered {
                    return Err(format!("{} uncovered", t.spec));
                }
            }
            Ok(())
        },
        no_shrink,
    );
}
