//! Chaos-layer integration tests: the fault/heterogeneity acceptance
//! contracts, end-to-end through planner + engine + serving simulators.
//!
//! * a single 4x straggler on an 8-device pool (concentrated routing):
//!   speed-aware LLEP prices the model step >= 2x faster than static EP;
//! * a permanent failure mid-serve: the sim recovers (elastic replan, no
//!   lost tokens, bounded recovery steps) and the whole run is
//!   bit-reproducible given (fault spec, scenario, system, seed);
//! * a P=1 pool whose sole device fails errors cleanly, never panics.

use llep::chaos::{FaultPlan, PoolState};
use llep::config::{ModelConfig, ModelPreset, SystemConfig, SystemPreset};
use llep::coordinator::{ContinuousBatchSim, Request, ServeSim};
use llep::exec::{Engine, PlanCostModel};
use llep::planner::PlannerKind;
use llep::routing::{DepthProfile, Scenario};
use llep::util::rng::Rng;

fn engine() -> Engine {
    Engine::modeled(
        ModelConfig::preset(ModelPreset::Fig1Layer),
        SystemConfig::preset(SystemPreset::H200x8),
    )
}

#[test]
fn straggler_4x_llep_model_step_at_least_2x_faster_than_ep() {
    // The acceptance scenario: one 4x straggler, 8 devices, concentrated
    // routing. The pool view comes from a FaultPlan so the whole spec ->
    // state -> pricing path is exercised.
    let faults = FaultPlan::parse("slow:dev=0,x=4").unwrap();
    let base = engine();
    let engine = base.for_pool(faults.state_at(0, &base.pool));
    assert!(engine.pool.is_degraded());

    let profile = DepthProfile::uniform(Scenario::concentrated(0.9, 1), 1);
    let mut rng = Rng::new(1);
    let lms = profile.generate_loads(&engine.model, 8, 16_384, &mut rng);
    let ep = engine.run_model(&lms, &PlannerKind::StandardEp).unwrap();
    let ll = engine.run_model(&lms, &PlannerKind::llep_default()).unwrap();
    assert!(!ep.stranded && !ll.stranded, "a straggler is slow, not dead");
    assert_eq!(ep.tokens, ll.tokens);
    assert!(
        ep.latency_s >= ll.latency_s * 2.0,
        "speed-aware LLEP must be >= 2x faster under the straggler: EP {} vs LLEP {}",
        ep.latency_s,
        ll.latency_s
    );
}

#[test]
fn permanent_failure_recovery_is_exact_bounded_and_bit_reproducible() {
    // Deterministic plan pricing so two runs are bit-comparable.
    let engine = engine().with_plan_cost(PlanCostModel::default());
    // 30k-token requests against the 64k batch budget: 2 per batch, so
    // 12 requests take 6 engine steps and the failure at step 2 lands
    // mid-run with several post-failure steps to recover over.
    let reqs: Vec<Request> =
        (0..12).map(|id| Request { id, arrival_s: 0.0, tokens: 30_000 }).collect();
    let faults = FaultPlan::parse("fail:dev=1,at=2").unwrap();
    let run = || {
        let sim = ServeSim::with_planner(
            engine.clone(),
            PlannerKind::llep_default().boxed(),
            Scenario::concentrated(0.8, 4),
            8192,
        )
        .with_faults(faults.clone());
        sim.try_run(&reqs, &mut Rng::new(9)).expect("chaos-aware LLEP must recover")
    };

    let a = run();
    assert_eq!(a.completed, 12, "every request completes despite the failure");
    assert!(a.tokens.is_exact(), "ledger conservation across the failure: {:?}", a.tokens);
    assert_eq!(a.chaos.failures, 1);
    assert_eq!(a.chaos.requeues, 1, "the in-flight step requeued exactly once");
    assert!(a.chaos.requeued_tokens > 0);
    assert!(a.chaos.wasted_s > 0.0, "the aborted attempt costs time");
    assert!(
        a.chaos.max_recovery_steps <= 1,
        "bounded recovery: one aborted attempt per failure, got {}",
        a.chaos.max_recovery_steps
    );
    assert!(a.chaos.fault_steps >= 4, "steps 2..6 run on the degraded pool");

    // Bit-reproducible given (fault spec, scenario, system, seed).
    let b = run();
    assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits(), "makespan bit-identical");
    assert_eq!(a.request_latency.p99.to_bits(), b.request_latency.p99.to_bits());
    assert_eq!(a.chaos, b.chaos);
    assert_eq!(a.tokens, b.tokens);
}

#[test]
fn static_ep_cannot_recover_from_the_same_failure() {
    let engine = engine().with_plan_cost(PlanCostModel::default());
    let reqs: Vec<Request> =
        (0..12).map(|id| Request { id, arrival_s: 0.0, tokens: 30_000 }).collect();
    let faults = FaultPlan::parse("fail:dev=0,at=2").unwrap();
    let sim = ServeSim::with_planner(
        engine,
        PlannerKind::StandardEp.boxed(),
        Scenario::concentrated(0.8, 4),
        8192,
    )
    .with_faults(faults);
    let err = sim.try_run(&reqs, &mut Rng::new(9)).unwrap_err();
    assert!(err.contains("dead device"), "{err}");
}

#[test]
fn sole_device_failure_errors_cleanly_instead_of_panicking() {
    // P=1 pool, the only device fails at step 0: both simulators must
    // return an error, not panic.
    let engine = Engine::modeled(
        ModelConfig::preset(ModelPreset::Tiny),
        SystemConfig::preset(SystemPreset::CpuSim8).with_devices(1),
    );
    let faults = FaultPlan::parse("fail:dev=0,at=0").unwrap();

    let reqs: Vec<Request> = vec![Request { id: 0, arrival_s: 0.0, tokens: 256 }];
    let serve = ServeSim::with_planner(
        engine.clone(),
        PlannerKind::llep_default().boxed(),
        Scenario::concentrated(0.9, 1),
        1024,
    )
    .with_faults(faults.clone());
    let err = serve.try_run(&reqs, &mut Rng::new(3)).unwrap_err();
    assert!(err.contains("no alive devices"), "{err}");

    let gen = ContinuousBatchSim::requests(2, 1e-4, (32, 64), (2, 4), &mut Rng::new(4));
    let cont = ContinuousBatchSim::with_planner(
        engine,
        PlannerKind::llep_default().boxed(),
        Scenario::concentrated(0.9, 1),
        1024,
    )
    .with_faults(faults);
    let err = cont.try_run(&gen, &mut Rng::new(5)).unwrap_err();
    assert!(err.contains("no alive devices"), "{err}");
}

#[test]
fn fail_then_recover_scales_the_pool_back_up() {
    let engine = engine().with_plan_cost(PlanCostModel::default());
    let reqs = ContinuousBatchSim::requests(6, 2e-5, (512, 1024), (6, 10), &mut Rng::new(11));
    let faults = FaultPlan::parse("fail:dev=3,at=1;recover:dev=3,at=4").unwrap();
    let sim = ContinuousBatchSim::with_planner(
        engine,
        PlannerKind::llep_default().boxed(),
        Scenario::concentrated(0.8, 4),
        16_384,
    )
    .with_faults(faults);
    let r = sim.try_run(&reqs, &mut Rng::new(12)).unwrap();
    assert_eq!(r.completed, 6);
    assert!(r.tokens.is_exact(), "{:?}", r.tokens);
    assert_eq!(r.chaos.failures, 1);
    assert_eq!(r.chaos.recoveries, 1, "the recover event rejoins the device");
    assert_eq!(r.chaos.fault_steps, 3, "degraded exactly for steps 1..4");
}

#[test]
fn straggler_serve_llep_beats_ep_end_to_end() {
    // Service-bound burst under a permanent 4x straggler: the chaos-aware
    // planner's makespan and tail latency beat static EP's.
    let faults = FaultPlan::parse("slow:dev=0,x=4").unwrap();
    let mut rng = Rng::new(13);
    let reqs = ServeSim::poisson_requests(24, 0.00005, 1024, 4096, &mut rng);
    let serve = |planner: PlannerKind| {
        ServeSim::with_planner(engine(), planner.boxed(), Scenario::concentrated(0.9, 1), 8192)
            .with_faults(faults.clone())
            .try_run(&reqs, &mut Rng::new(14))
            .unwrap()
    };
    let ep = serve(PlannerKind::StandardEp);
    let ll = serve(PlannerKind::llep_default());
    assert_eq!(ep.completed, 24);
    assert_eq!(ll.completed, 24);
    assert!(ep.tokens.is_exact() && ll.tokens.is_exact());
    assert!(
        ll.makespan_s * 2.0 < ep.makespan_s,
        "LLEP {} vs EP {} under the straggler",
        ll.makespan_s,
        ep.makespan_s
    );
    assert!(ll.request_latency.p99 < ep.request_latency.p99, "degraded tail improves too");
    assert!(ep.chaos.fault_steps > 0 && ll.chaos.fault_steps > 0);
}

#[test]
fn mixed_generation_preset_flows_into_the_engine_pool() {
    // The heterogeneous preset alone (no injected faults) degrades the
    // pool view; pool-aware LLEP beats EP even on *balanced* routing,
    // because equal token counts are unequal completion times.
    let engine = Engine::modeled(
        ModelConfig::preset(ModelPreset::Fig1Layer),
        SystemConfig::preset(SystemPreset::MixedH100A100),
    );
    assert!(engine.pool.is_degraded(), "preset speeds reach the pool");
    assert_eq!(engine.pool.alive_count(), 8);

    let mut rng = Rng::new(21);
    let lm = Scenario::balanced().generate_loads(&engine.model, 8, 32_768, &mut rng);
    let ep = engine.run_step_loads(&lm, &PlannerKind::StandardEp);
    let ll = engine.run_step_loads(&lm, &PlannerKind::llep_default());
    assert!(!ep.stranded && !ll.stranded);
    assert!(
        ll.latency_s < ep.latency_s,
        "speed-aware LLEP exploits the fast half: LLEP {} vs EP {}",
        ll.latency_s,
        ep.latency_s
    );
    // EP's critical path is an A100; LLEP's normalized balance shrinks
    // the worst normalized completion time.
    assert!(ll.phases.compute_s < ep.phases.compute_s);
}

#[test]
fn jitter_and_link_events_are_reproducible_through_serving() {
    let engine = engine().with_plan_cost(PlanCostModel::default());
    let reqs: Vec<Request> =
        (0..6).map(|id| Request { id, arrival_s: 0.0, tokens: 30_000 }).collect();
    let faults = FaultPlan::parse("jitter:amp=0.3,seed=5;link:x=2,from=1").unwrap();
    let run = || {
        ServeSim::with_planner(
            engine.clone(),
            PlannerKind::llep_default().boxed(),
            Scenario::concentrated(0.9, 1),
            8192,
        )
        .with_faults(faults.clone())
        .try_run(&reqs, &mut Rng::new(31))
        .unwrap()
    };
    let a = run();
    let b = run();
    assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits());
    assert!(a.chaos.fault_steps > 0, "jitter degrades every step");
    assert!(a.tokens.is_exact());
}

#[test]
fn pool_state_round_trips_through_fault_plan_composition() {
    // FaultPlan events compose over a heterogeneous system base pool.
    let sys = SystemConfig::preset(SystemPreset::MixedH100A100);
    let engine = Engine::modeled(ModelConfig::preset(ModelPreset::Fig1Layer), sys);
    let plan = FaultPlan::parse("slow:dev=4,x=2;fail:dev=7,at=0").unwrap();
    let pool = plan.state_at(0, &engine.pool);
    assert_eq!(pool.devices[4].speed, 0.33 / 2.0, "fault stacks on the preset speed");
    assert!(!pool.devices[7].alive);
    assert_eq!(pool.alive_count(), 7);
    // The healthy pool comparison stays untouched.
    assert_eq!(PoolState::healthy(8).alive_count(), 8);
}
