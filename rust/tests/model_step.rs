//! Integration/property tests for the multi-layer pipelined engine path
//! ([`Engine::run_model`]): the pipelined-latency identity, plan
//! composability (batching layers must not change any layer's plan), and
//! conservation across depth — checked over randomized depth profiles.

use llep::config::{ModelConfig, ModelPreset, SystemConfig, SystemPreset};
use llep::exec::Engine;
use llep::planner::PlannerKind;
use llep::routing::{DepthProfile, Scenario};
use llep::util::prop::{assert_property, no_shrink};
use llep::util::rng::Rng;

fn engine(layers: usize) -> Engine {
    let mut model = ModelConfig::preset(ModelPreset::Fig1Layer);
    model.num_layers = layers;
    Engine::modeled(model, SystemConfig::preset(SystemPreset::H200x8))
}

/// A random multi-layer workload.
#[derive(Clone, Debug)]
struct Workload {
    layers: usize,
    tokens: usize,
    seed: u64,
    /// Per-layer (concentration, hot) pairs; concentration 0 = balanced.
    shape: Vec<(f64, usize)>,
}

fn gen_workload(rng: &mut Rng) -> Workload {
    let layers = rng.range(1, 12);
    Workload {
        layers,
        tokens: [1024usize, 4096, 16_384][rng.index(3)],
        seed: rng.next_u64(),
        shape: (0..layers)
            .map(|_| (rng.f64(), [1usize, 4, 16][rng.index(3)]))
            .collect(),
    }
}

fn profile_for(w: &Workload) -> DepthProfile {
    DepthProfile::from_scenarios(
        w.shape
            .iter()
            .map(|&(c, hot)| {
                if c < 0.05 {
                    Scenario::balanced()
                } else {
                    Scenario::concentrated(c, hot)
                }
            })
            .collect(),
    )
}

/// The virtual-clock contract of the pipeline: the model-step latency is
/// exactly the sum of per-layer collective latencies minus the planning
/// time hidden behind execution (`overlap_saved_s`), and overlap can
/// never exceed what the layers' planning phases cost in total.
#[test]
fn pipelined_latency_identity_holds_for_any_profile() {
    assert_property(
        "model latency = serial - overlap",
        11,
        40,
        gen_workload,
        |w| {
            let e = engine(w.layers);
            let profile = profile_for(w);
            let mut rng = Rng::new(w.seed);
            let lms = profile.generate_loads(&e.model, 8, w.tokens, &mut rng);
            let r = e.run_model(&lms, &PlannerKind::llep_default())?;
            let identity = r.serial_latency_s - r.overlap_saved_s;
            let tol = 1e-9 * r.serial_latency_s.max(1e-30);
            if (r.latency_s - identity).abs() > tol {
                return Err(format!(
                    "latency {} != serial {} - overlap {}",
                    r.latency_s, r.serial_latency_s, r.overlap_saved_s
                ));
            }
            if r.latency_s > r.serial_latency_s + tol {
                return Err("pipelining made the step slower".into());
            }
            let plan_total: f64 =
                r.layers.iter().map(|l| l.report.phases.meta_s + l.report.phases.plan_s).sum();
            if r.overlap_saved_s > plan_total + tol {
                return Err(format!(
                    "overlap {} exceeds total planning cost {plan_total}",
                    r.overlap_saved_s
                ));
            }
            Ok(())
        },
        no_shrink,
    );
}

/// Per-layer plans must be identical to planning each layer on its own:
/// batching layers into one model step is a scheduling change, not a
/// routing change.
#[test]
fn model_step_plans_equal_independent_plans() {
    assert_property(
        "plan composability",
        13,
        25,
        gen_workload,
        |w| {
            let e = engine(w.layers);
            let profile = profile_for(w);
            let mut rng = Rng::new(w.seed);
            let lms = profile.generate_loads(&e.model, 8, w.tokens, &mut rng);
            for kind in [PlannerKind::StandardEp, PlannerKind::llep_default()] {
                let r = e.run_model(&lms, &kind)?;
                for (i, (layer, lm)) in r.layers.iter().zip(&lms).enumerate() {
                    let independent = kind.plan(8, &lm.expert_loads(), Some(&e.topo));
                    if layer.plan != independent {
                        return Err(format!("{}: layer {i} plan differs", kind.label()));
                    }
                }
            }
            Ok(())
        },
        no_shrink,
    );
}

/// Per-layer reports inside a model step carry exactly the deterministic
/// quantities a stand-alone step over the same loads reports.
#[test]
fn model_step_layers_match_standalone_steps() {
    let e = engine(5);
    let profile = DepthProfile::varying(&e.model, 0.4, 0.3);
    let mut rng = Rng::new(42);
    let lms = profile.generate_loads(&e.model, 8, 8192, &mut rng);
    let r = e.run_model(&lms, &PlannerKind::llep_default()).unwrap();
    assert_eq!(r.num_layers(), 5);
    for (layer, lm) in r.layers.iter().zip(&lms) {
        let standalone = e.run_step_loads(lm, &PlannerKind::llep_default());
        assert_eq!(layer.report.device_compute_s, standalone.device_compute_s);
        assert_eq!(layer.report.device_peak_bytes, standalone.device_peak_bytes);
        assert_eq!(layer.report.bytes_dispatch, standalone.bytes_dispatch);
        assert_eq!(layer.report.bytes_combine, standalone.bytes_combine);
        assert_eq!(layer.report.bytes_weights, standalone.bytes_weights);
        assert_eq!(layer.report.gemm_calls, standalone.gemm_calls);
        assert_eq!(layer.report.tokens, standalone.tokens);
    }
}

/// Tokens are conserved across depth: every layer of a model step prices
/// the same batch, and the step's token count is the batch's (tokens are
/// not multiplied by layer count).
#[test]
fn tokens_counted_once_per_step() {
    let e = engine(8);
    let profile = DepthProfile::uniform(Scenario::concentrated(0.8, 4), 8);
    let mut rng = Rng::new(7);
    let lms = profile.generate_loads(&e.model, 8, 2048, &mut rng);
    let r = e.run_model(&lms, &PlannerKind::llep_default()).unwrap();
    assert_eq!(r.tokens, 8 * 2048);
    for layer in &r.layers {
        assert_eq!(layer.report.tokens, 8 * 2048);
    }
    // throughput uses the pipelined clock
    assert!((r.throughput() - r.tokens as f64 / r.latency_s).abs() < 1e-9);
}

/// Multi-layer LLEP against multi-layer EP on a depth-varying imbalance
/// profile: the speedup survives depth (every layer is imbalanced, just
/// differently), and per-layer fallback happens only where routing is
/// balanced.
#[test]
fn depth_varying_imbalance_speedup() {
    let e = engine(12);
    let profile = DepthProfile::varying(&e.model, 0.5, 0.2);
    let mut rng = Rng::new(3);
    let lms = profile.generate_loads(&e.model, 8, 16_384, &mut rng);
    let ep = e.run_model(&lms, &PlannerKind::StandardEp).unwrap();
    let ll = e.run_model(&lms, &PlannerKind::llep_default()).unwrap();
    let speedup = ep.latency_s / ll.latency_s;
    assert!(speedup > 1.5, "depth-varying speedup too small: {speedup:.2}");
    assert!(ll.max_peak_bytes() < ep.max_peak_bytes());
    assert_eq!(ll.fallback_layers, 0, "every layer is imbalanced here");
}
