//! Integration: exactness of the distributed execution across random
//! routings, planners and batch shapes — forward outputs AND accumulated
//! expert-weight gradients must match the single-device reference
//! (paper: "LLEP is an **exact** MoE computation algorithm").

use llep::config::{LlepConfig, ModelConfig, ModelPreset, SystemConfig, SystemPreset};
use llep::exec::{run_backward_real, run_step_real, Engine, NativeCompute};
use llep::moe::{backward_reference, forward_reference, route, MoeLayer};
use llep::planner::PlannerKind;
use llep::routing::Scenario;
use llep::tensor::Mat;
use llep::util::rng::Rng;

fn max_diff(a: &[Mat], b: &[Mat]) -> f32 {
    a.iter()
        .zip(b)
        .flat_map(|(x, y)| x.data.iter().zip(&y.data).map(|(u, v)| (u - v).abs()))
        .fold(0f32, f32::max)
}

fn engine4() -> (ModelConfig, Engine) {
    let model = ModelConfig::preset(ModelPreset::Tiny);
    let engine = Engine::modeled(model.clone(), SystemConfig::preset(SystemPreset::CpuSim4));
    (model, engine)
}

#[test]
fn forward_exact_across_random_scenarios_and_planners() {
    let (model, engine) = engine4();
    let mut rng = Rng::new(100);
    let scenarios = [
        Scenario::balanced(),
        Scenario::concentrated(0.95, 1),
        Scenario::concentrated(0.6, 3),
        Scenario::power_law(1.5),
        Scenario::drifting(5, 0.4, 0.3),
    ];
    let planners = [
        PlannerKind::StandardEp,
        PlannerKind::Llep(LlepConfig { alpha: 1.0, min_gemm_tokens: 1, lambda: 1.0 }),
        PlannerKind::Llep(LlepConfig { alpha: 1.5, min_gemm_tokens: 8, lambda: 1.1 }),
        PlannerKind::Eplb { replicas: 6 },
    ];
    for (i, sc) in scenarios.iter().enumerate() {
        let layer = MoeLayer::random(&model, &mut rng);
        let tokens = 16 + i * 7; // vary batch shapes
        let routing = sc.generate(&model, 4, tokens, &mut rng);
        let xs: Vec<Mat> =
            (0..4).map(|_| Mat::randn(tokens, model.d_model, 0.5, &mut rng)).collect();
        let reference = forward_reference(&layer, &xs, &routing);
        for kind in &planners {
            let step = run_step_real(&engine, &layer, &xs, &routing, kind, &NativeCompute)
                .unwrap_or_else(|e| panic!("{} on {}: {e}", kind.label(), sc.label()));
            let d = max_diff(&reference, &step.outputs);
            assert!(d < 1e-4, "{} on {}: diff {d}", kind.label(), sc.label());
        }
    }
}

#[test]
fn forward_exact_with_real_router() {
    // Routing produced by the actual softmax top-K router, not synthetic.
    let (model, engine) = engine4();
    let mut rng = Rng::new(200);
    for seed in 0..3 {
        let layer = MoeLayer::random(&model, &mut Rng::new(seed));
        let xs: Vec<Mat> =
            (0..4).map(|_| Mat::randn(20, model.d_model, 0.8, &mut rng)).collect();
        let routing = route(&layer, &xs);
        let reference = forward_reference(&layer, &xs, &routing);
        let step = run_step_real(
            &engine,
            &layer,
            &xs,
            &routing,
            &PlannerKind::Llep(LlepConfig { alpha: 1.0, min_gemm_tokens: 2, lambda: 1.0 }),
            &NativeCompute,
        )
        .unwrap();
        assert!(max_diff(&reference, &step.outputs) < 1e-4);
    }
}

#[test]
fn backward_exact_and_spilled_grads_return_home() {
    let (model, engine) = engine4();
    let mut rng = Rng::new(300);
    let layer = MoeLayer::random(&model, &mut rng);
    let routing = Scenario::concentrated(0.9, 1).generate(&model, 4, 40, &mut rng);
    let xs: Vec<Mat> = (0..4).map(|_| Mat::randn(40, model.d_model, 0.5, &mut rng)).collect();
    let dys: Vec<Mat> = (0..4).map(|_| Mat::randn(40, model.d_model, 0.5, &mut rng)).collect();

    let reference = backward_reference(&layer, &xs, &routing, &dys);
    for kind in [
        PlannerKind::StandardEp,
        PlannerKind::Llep(LlepConfig { alpha: 1.0, min_gemm_tokens: 4, lambda: 1.0 }),
    ] {
        let step = run_step_real(&engine, &layer, &xs, &routing, &kind, &NativeCompute).unwrap();
        let bwd = run_backward_real(&engine, &layer, &xs, &routing, &dys, &step.plan).unwrap();
        for (e, (got, want)) in bwd.grads.iter().zip(&reference).enumerate() {
            let d = got.max_abs_diff(want);
            assert!(d < 2e-3, "{}: expert {e} grad diff {d}", kind.label());
        }
        if !step.plan.transfers.is_empty() {
            assert!(bwd.grad_return_bytes > 0, "spilled grads must be returned");
        } else {
            assert_eq!(bwd.grad_return_bytes, 0);
        }
    }
}

#[test]
fn step_report_consistent_with_plan() {
    let (model, engine) = engine4();
    let mut rng = Rng::new(400);
    let layer = MoeLayer::random(&model, &mut rng);
    let routing = Scenario::concentrated(0.8, 2).generate(&model, 4, 64, &mut rng);
    let xs: Vec<Mat> = (0..4).map(|_| Mat::randn(64, model.d_model, 0.5, &mut rng)).collect();
    let kind = PlannerKind::Llep(LlepConfig { alpha: 1.0, min_gemm_tokens: 4, lambda: 1.0 });
    let step = run_step_real(&engine, &layer, &xs, &routing, &kind, &NativeCompute).unwrap();
    assert_eq!(step.report.weight_transfers, step.plan.transfers.len());
    assert_eq!(step.report.gemm_calls, step.plan.gemm_calls());
    assert_eq!(step.report.tokens, 4 * 64);
    // measured compute charged somewhere
    assert!(step.report.device_compute_s.iter().sum::<f64>() > 0.0);
}

#[test]
fn empty_device_and_unrouted_expert_edge_cases() {
    let (model, engine) = engine4();
    let mut rng = Rng::new(500);
    let layer = MoeLayer::random(&model, &mut rng);
    // all tokens on device 0, all to expert 3 only
    let tokens = 12;
    let routing = llep::routing::Routing {
        num_experts: model.num_experts,
        top_k: model.top_k,
        experts: vec![
            (0..tokens).flat_map(|_| [3u32, 5u32]).collect(),
            vec![],
            vec![],
            vec![],
        ],
        gates: vec![(0..tokens).flat_map(|_| [0.7f32, 0.3f32]).collect(), vec![], vec![], vec![]],
    };
    routing.validate().unwrap();
    let xs = vec![
        Mat::randn(tokens, model.d_model, 0.5, &mut rng),
        Mat::zeros(0, model.d_model),
        Mat::zeros(0, model.d_model),
        Mat::zeros(0, model.d_model),
    ];
    let reference = forward_reference(&layer, &xs, &routing);
    for kind in [
        PlannerKind::StandardEp,
        PlannerKind::Llep(LlepConfig { alpha: 1.0, min_gemm_tokens: 1, lambda: 1.0 }),
    ] {
        let step = run_step_real(&engine, &layer, &xs, &routing, &kind, &NativeCompute).unwrap();
        assert!(max_diff(&reference, &step.outputs) < 1e-4, "{}", kind.label());
    }
}
