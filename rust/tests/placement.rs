//! Integration tests for the `placement/` subsystem: the persistent
//! re-layout decorator `placed(<inner>)` end-to-end through the engine.
//!
//! Acceptance contracts:
//! * drifting hotspot: `placed(llep)` moves strictly fewer weight bytes
//!   than bare LLEP (which re-buys the same spill transfers every step)
//!   and prices a strictly lower mean step latency than stale-stats EPLB
//!   (whose placement serializes every regime change);
//! * the layout evolution is a bit-reproducible function of
//!   (spec, scenario, seed);
//! * under a device failure a standby-backed layout strands zero steps
//!   (and actually promotes), strictly fewer than EPLB without standby;
//! * a cache wrapped around `placed(...)` keys entries to the layout
//!   generation: plans are never reused across a re-layout
//!   (property-tested over random drift sequences).

use llep::chaos::PoolState;
use llep::config::{ModelConfig, ModelPreset, SystemConfig, SystemPreset};
use llep::exec::{Engine, PlanCostModel};
use llep::planner::{CacheOutcome, Planner, Registry};
use llep::routing::LoadMatrix;
use llep::util::prop::{assert_property, no_shrink};
use llep::util::rng::Rng;

const DEVICES: usize = 4;
const EXPERTS: usize = 16;
const HOT: u64 = 16_000;
const COLD: u64 = 100;

/// Fig. 1 layer shrunk to 16 experts on 4 devices: each device natively
/// hosts 4 experts, so a 4-expert hotspot collides entirely on one
/// device — the regime where a persistent re-layout pays.
fn engine() -> Engine {
    let mut model = ModelConfig::preset(ModelPreset::Fig1Layer);
    model.num_experts = EXPERTS;
    Engine::modeled(model, SystemConfig::preset(SystemPreset::H200x8).with_devices(DEVICES))
        .with_plan_cost(PlanCostModel::default())
}

/// All tokens originate on device 0 (K=1): planners and pricing only
/// consume per-expert totals and origin rows.
fn lm_from_loads(loads: &[u64], devices: usize) -> LoadMatrix {
    let mut counts = vec![vec![0u64; loads.len()]; devices];
    counts[0] = loads.to_vec();
    LoadMatrix { counts, top_k: 1 }
}

/// Four hot experts, all native to device `phase` under the identity
/// layout (native(e) = e / 4).
fn loads_for_phase(phase: usize) -> Vec<u64> {
    let lo = phase * 4;
    (0..EXPERTS).map(|e| if e >= lo && e < lo + 4 { HOT } else { COLD }).collect()
}

/// The drifting-hotspot scenario: the hot set rotates one device's worth
/// of experts every `phase_len` steps.
fn drifting_hotspot(steps: usize, phase_len: usize) -> Vec<Vec<u64>> {
    (0..steps).map(|t| loads_for_phase((t / phase_len) % DEVICES)).collect()
}

struct RunTotals {
    weight_bytes: u64,
    mean_latency_s: f64,
    migrations: u64,
    stranded_steps: usize,
}

/// Drive one planner over the scenario. With `stale_stats` the planner
/// sees the previous step's loads as placement statistics (EPLB's
/// time-delayed placement); pricing always uses the true loads.
fn run(e: &Engine, loads_seq: &[Vec<u64>], planner: &dyn Planner, stale_stats: bool) -> RunTotals {
    let mut totals = RunTotals {
        weight_bytes: 0,
        mean_latency_s: 0.0,
        migrations: 0,
        stranded_steps: 0,
    };
    let mut prev: Option<LoadMatrix> = None;
    for loads in loads_seq {
        let lm = lm_from_loads(loads, DEVICES);
        let r = if stale_stats {
            let stats = prev.as_ref().unwrap_or(&lm);
            e.run_step_loads_with_stats(&lm, stats, planner)
        } else {
            e.run_step_loads(&lm, planner)
        };
        assert!(!r.oom, "scenario must fit in memory");
        totals.weight_bytes += r.bytes_weights + r.placement.migration_bytes;
        totals.mean_latency_s += r.latency_s;
        totals.migrations += r.placement.migrations;
        totals.stranded_steps += usize::from(r.stranded);
        prev = Some(lm);
    }
    totals.mean_latency_s /= loads_seq.len() as f64;
    totals
}

#[test]
fn placed_llep_beats_llep_on_bytes_and_stale_eplb_on_latency() {
    let e = engine();
    let seq = drifting_hotspot(32, 8);
    let reg = Registry::builtin();

    let placed = reg.parse("placed(llep)").unwrap();
    let llep = reg.parse("llep").unwrap();
    let eplb = reg.parse("eplb").unwrap();

    let p = run(&e, &seq, &*placed, false);
    let l = run(&e, &seq, &*llep, false);
    // EPLB places experts from the previous step's statistics — the
    // honest serving regime, where every phase change is a surprise.
    let b = run(&e, &seq, &*eplb, true);

    assert!(p.migrations > 0, "the drifting hotspot must trigger re-layouts");
    assert_eq!(l.migrations, 0, "bare LLEP owns no layout");
    assert_eq!(p.stranded_steps + l.stranded_steps + b.stranded_steps, 0);

    // Bare LLEP re-ships the same expert weights as spill transfers on
    // every step of every phase; the persistent layout pays a few
    // migration legs per regime and then serves transfer-free.
    assert!(
        p.weight_bytes < l.weight_bytes,
        "placed(llep) must move fewer cumulative weight bytes: {} vs {}",
        p.weight_bytes,
        l.weight_bytes
    );

    // Stale-stats EPLB serializes the whole new hot set on one device at
    // every phase boundary; placed(llep) spills with *current* loads
    // while the layout adapts, so its regime-change steps stay cheap.
    assert!(
        p.mean_latency_s < b.mean_latency_s,
        "placed(llep) must price a lower mean step latency: {} vs {}",
        p.mean_latency_s,
        b.mean_latency_s
    );
}

#[test]
fn placement_evolution_is_bit_reproducible() {
    // The layout evolution (and everything priced from it) is a pure
    // function of (spec, scenario, seed): two fresh parses of the same
    // spec replay the same migrations at the same steps and price every
    // step bit-identically.
    let e = engine();
    let seq = drifting_hotspot(24, 6);
    let spec = "placed(llep):ema=0.25,budget=4,horizon=32,standby=1";

    let evolve = || -> Vec<(u64, u64, u64, u64)> {
        let p = Registry::builtin().parse(spec).unwrap();
        seq.iter()
            .map(|loads| {
                let r = e.run_step_loads(&lm_from_loads(loads, DEVICES), &*p);
                (
                    r.latency_s.to_bits(),
                    r.placement.relayouts,
                    r.placement.migrations,
                    r.placement.migration_bytes,
                )
            })
            .collect()
    };

    let a = evolve();
    let b = evolve();
    assert_eq!(a, b, "placement evolution must be bit-reproducible");
    assert!(a.iter().any(|&(_, _, m, _)| m > 0), "the scenario must actually migrate");
}

#[test]
fn standby_promotion_recovers_with_fewer_stranded_steps_than_eplb() {
    // A hot expert's device dies mid-run. The standby-backed layout
    // promotes the warm replica (free failover) and the pool-aware inner
    // planner spills the rest — zero stranded steps. EPLB keeps placing
    // work on the dead device and strands every post-failure step.
    let e = engine();
    let mut loads = vec![COLD; EXPERTS];
    loads[0] = HOT; // hot expert 0, native to device 0
    let lm = lm_from_loads(&loads, DEVICES);

    let mut pool = PoolState::healthy(DEVICES);
    pool.devices[0].alive = false;
    let e_dead = e.for_pool(pool);

    let drive = |planner: &dyn Planner, stale_stats: bool| -> (usize, u64) {
        let mut stranded = 0usize;
        let mut promotions = 0u64;
        for phase in 0..2 {
            let eng = if phase == 0 { &e } else { &e_dead };
            for _ in 0..4 {
                let r = if stale_stats {
                    eng.run_step_loads_with_stats(&lm, &lm, planner)
                } else {
                    eng.run_step_loads(&lm, planner)
                };
                stranded += usize::from(r.stranded);
                promotions += r.placement.standby_promotions;
            }
        }
        (stranded, promotions)
    };

    let placed =
        Registry::builtin().parse("placed(llep):ema=0.25,budget=4,horizon=32,standby=1").unwrap();
    let eplb = Registry::builtin().parse("eplb").unwrap();

    let (placed_stranded, promotions) = drive(&*placed, false);
    let (eplb_stranded, _) = drive(&*eplb, true);

    assert!(promotions >= 1, "the dead hot device must promote its standby");
    assert_eq!(placed_stranded, 0, "standby + pool-aware spill strand nothing");
    assert!(eplb_stranded >= 1, "EPLB keeps placing work on the dead device");
    assert!(placed_stranded < eplb_stranded, "strictly fewer stranded steps");
}

#[test]
fn cached_placed_hits_within_a_regime_and_misses_across_relayouts() {
    // Deterministic companion to the property below: hits actually occur
    // inside a stable regime, and a re-layout actually invalidates.
    let cached = Registry::builtin().parse("cached(placed(llep))").unwrap();
    let a = loads_for_phase(0);
    let b = loads_for_phase(1);

    let _ = cached.plan(DEVICES, &a, None); // cold miss; hotspot re-lays-out
    let gen = cached.layout_generation();
    assert!(gen > 0, "colliding hotspot must move the layout");
    let _ = cached.plan(DEVICES, &a, None);
    assert_eq!(cached.last_cache_outcome(), Some(CacheOutcome::Hit));
    assert_eq!(cached.layout_generation(), gen, "a reused plan never moves the layout");

    let _ = cached.plan(DEVICES, &b, None); // new regime: fresh plan + re-layout
    assert!(cached.layout_generation() > gen, "new hotspot must move the layout");
    let _ = cached.plan(DEVICES, &a, None);
    assert_eq!(
        cached.last_cache_outcome(),
        Some(CacheOutcome::Miss),
        "the old entry is keyed to a dead generation and must not serve"
    );
}

#[test]
fn prop_cache_never_reuses_plans_across_layout_generations() {
    // Over random drift sequences (the hot set jumps between the four
    // device-aligned regimes, revisiting old ones), every cache hit must
    // come from an entry installed under the *current* layout
    // generation, must not itself move the layout, and must carry no
    // migration transfers. `placed(...)` publishes no repair params, so
    // the repair tier must never fire across an evolved layout.
    assert_property(
        "cache keyed to layout generation",
        0x9_1ACE,
        40,
        |rng: &mut Rng| (0..(6 + rng.index(10))).map(|_| rng.index(DEVICES)).collect(),
        |seq: &Vec<usize>| {
            let cached = Registry::builtin().parse("cached(placed(llep))").unwrap();
            let mut installed_gen: [Option<u64>; DEVICES] = [None; DEVICES];
            for &phase in seq {
                let loads = loads_for_phase(phase);
                let gen_before = cached.layout_generation();
                let plan = cached.plan(DEVICES, &loads, None);
                let gen_after = cached.layout_generation();
                let planned: u64 = plan.device_loads().iter().sum();
                let total: u64 = loads.iter().sum();
                if planned != total {
                    return Err(format!("token conservation: planned {planned} of {total}"));
                }
                match cached.last_cache_outcome() {
                    Some(CacheOutcome::Hit) => {
                        if gen_after != gen_before {
                            return Err("a reused plan moved the layout".into());
                        }
                        if installed_gen[phase] != Some(gen_after) {
                            return Err(format!(
                                "hit served across layout generations: entry {:?}, now {}",
                                installed_gen[phase], gen_after
                            ));
                        }
                        if !plan.migrations.is_empty() {
                            return Err("cached entry carried a one-shot migration".into());
                        }
                    }
                    Some(CacheOutcome::Repaired) => {
                        return Err("placed(...) publishes no repair params".into());
                    }
                    _ => {
                        // Fresh plan (miss or forced refresh): the entry it
                        // installed is keyed to the post-round generation.
                        installed_gen[phase] = Some(gen_after);
                    }
                }
            }
            Ok(())
        },
        no_shrink,
    );
}
