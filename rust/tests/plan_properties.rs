//! Property-based tests over the planners: random loads through
//! EP/LLEP/EPLB must always produce valid, capacity-respecting, exact
//! plans, and the LLEP plan must never be worse than EP on the
//! balance metric it optimizes.

use llep::config::LlepConfig;
use llep::planner::validate::{validate_capacity, validate_plan};
use llep::planner::PlannerKind;
use llep::util::prop::{assert_property, no_shrink};
use llep::util::rng::Rng;

/// A random planner input: (N, P, loads, llep config).
#[derive(Clone, Debug)]
struct Input {
    n: usize,
    p: usize,
    loads: Vec<u64>,
    alpha: f64,
    min_chunk: usize,
    lambda: f64,
}

fn gen_input(rng: &mut Rng) -> Input {
    let p = *[2usize, 4, 8].get(rng.index(3)).unwrap();
    let m = rng.range(1, 6);
    let n = p * m;
    // loads with a mixture of zeros, small and huge values
    let loads: Vec<u64> = (0..n)
        .map(|_| match rng.index(4) {
            0 => 0,
            1 => rng.below(50),
            2 => rng.below(5_000),
            _ => rng.below(500_000),
        })
        .collect();
    Input {
        n,
        p,
        loads,
        alpha: 1.0 + rng.f64() * 2.0,
        min_chunk: [1usize, 16, 256, 1024][rng.index(4)],
        lambda: 1.0 + rng.f64() * 2.0,
    }
}

fn shrink_input(input: &Input) -> Vec<Input> {
    let mut out = Vec::new();
    // halve each load
    let mut halved = input.clone();
    for l in halved.loads.iter_mut() {
        *l /= 2;
    }
    if halved.loads != input.loads {
        out.push(halved);
    }
    // zero one load at a time (first few)
    for i in 0..input.loads.len().min(4) {
        if input.loads[i] != 0 {
            let mut z = input.clone();
            z.loads[i] = 0;
            out.push(z);
        }
    }
    out
}

#[test]
fn llep_plans_are_always_valid() {
    assert_property(
        "llep valid",
        0xA11CE,
        500,
        gen_input,
        |input| {
            let cfg = LlepConfig {
                alpha: input.alpha,
                min_gemm_tokens: input.min_chunk,
                lambda: input.lambda,
            };
            let plan = PlannerKind::Llep(cfg).plan(input.p, &input.loads, None);
            validate_plan(&plan, &input.loads)?;
            validate_capacity(&plan, &input.loads, input.alpha)
        },
        shrink_input,
    );
}

#[test]
fn ep_and_eplb_plans_are_always_valid() {
    assert_property(
        "ep+eplb valid",
        0xB0B,
        300,
        gen_input,
        |input| {
            let ep = PlannerKind::StandardEp.plan(input.p, &input.loads, None);
            validate_plan(&ep, &input.loads)?;
            let eplb =
                PlannerKind::Eplb { replicas: input.p * 2 }.plan(input.p, &input.loads, None);
            validate_plan(&eplb, &input.loads)
        },
        shrink_input,
    );
}

#[test]
fn llep_never_increases_max_device_load() {
    // The balance objective: LLEP's most-loaded device must never hold
    // more tokens than EP's most-loaded device.
    assert_property(
        "llep max load <= ep max load",
        0xC0FFEE,
        500,
        gen_input,
        |input| {
            let cfg = LlepConfig {
                alpha: input.alpha,
                min_gemm_tokens: input.min_chunk,
                lambda: input.lambda,
            };
            let ep = PlannerKind::StandardEp.plan(input.p, &input.loads, None);
            let ll = PlannerKind::Llep(cfg).plan(input.p, &input.loads, None);
            let ep_max = ep.device_loads().into_iter().max().unwrap_or(0);
            let ll_max = ll.device_loads().into_iter().max().unwrap_or(0);
            if ll_max <= ep_max {
                Ok(())
            } else {
                Err(format!("LLEP max {ll_max} > EP max {ep_max}"))
            }
        },
        shrink_input,
    );
}

#[test]
fn llep_total_tokens_conserved() {
    assert_property(
        "token conservation",
        0xDEAD,
        500,
        gen_input,
        |input| {
            let cfg = LlepConfig {
                alpha: input.alpha,
                min_gemm_tokens: input.min_chunk,
                lambda: input.lambda,
            };
            let plan = PlannerKind::Llep(cfg).plan(input.p, &input.loads, None);
            let total: u64 = input.loads.iter().sum();
            let assigned: u64 = plan.device_loads().iter().sum();
            if total == assigned {
                Ok(())
            } else {
                Err(format!("{assigned} assigned of {total}"))
            }
        },
        shrink_input,
    );
}

#[test]
fn lambda_guard_matches_imbalance_ratio() {
    assert_property(
        "lambda guard",
        0xFEED,
        300,
        gen_input,
        |input| {
            let cfg = LlepConfig {
                alpha: input.alpha,
                min_gemm_tokens: input.min_chunk,
                lambda: input.lambda,
            };
            let ratio = llep::routing::imbalance_ratio(&input.loads);
            let plan = PlannerKind::Llep(cfg).plan(input.p, &input.loads, None);
            if (ratio < input.lambda) != plan.fallback_ep {
                return Err(format!(
                    "ratio {ratio} lambda {} but fallback={}",
                    input.lambda, plan.fallback_ep
                ));
            }
            if plan.fallback_ep && !plan.transfers.is_empty() {
                return Err("fallback plan must have no transfers".into());
            }
            Ok(())
        },
        shrink_input,
    );
}

#[test]
fn min_chunk_respected_by_spills() {
    // Every spilled (foreign, unforced) segment must hold >= m tokens OR
    // be the final remainder of its expert.
    assert_property(
        "min chunk",
        0xFACE,
        400,
        gen_input,
        |input| {
            let cfg = LlepConfig {
                alpha: input.alpha,
                min_gemm_tokens: input.min_chunk,
                lambda: 1.0, // always engage LLA
            };
            let plan = PlannerKind::Llep(cfg).plan(input.p, &input.loads, None);
            if plan.fallback_ep {
                return Ok(());
            }
            let m = input.n / input.p;
            for (e, segs) in plan.assignments.iter().enumerate() {
                let native = e / m;
                for s in segs {
                    if s.device != native
                        && !s.forced
                        && s.len() < input.min_chunk as u64
                        && s.end != input.loads[e]
                    {
                        return Err(format!(
                            "expert {e}: undersized spill {s:?} (m={})",
                            input.min_chunk
                        ));
                    }
                }
            }
            Ok(())
        },
        no_shrink,
    );
}
