//! Integration tests over the PJRT runtime: AOT artifacts loaded and
//! executed from rust, cross-checked against the native reference.
//!
//! These tests require `make artifacts` to have produced `artifacts/`;
//! they are skipped (with a loud message) when it is missing so that
//! `cargo test` stays green on a fresh checkout.

use llep::config::{LlepConfig, ModelConfig, ModelPreset, SystemConfig, SystemPreset};
use llep::exec::{run_step_real, Engine, ExpertCompute, NativeCompute};
use llep::moe::{ffn_forward, forward_reference, MoeLayer};
use llep::planner::PlannerKind;
use llep::routing::Routing;
use llep::runtime::{PjrtCompute, Runtime};
use llep::tensor::Mat;
use llep::util::rng::Rng;
use std::path::PathBuf;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = std::env::var("LLEP_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"));
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts not built at {dir:?} — run `make artifacts`");
        None
    }
}

/// Tiny-model geometry must match the python side (model.py).
fn tiny_model() -> ModelConfig {
    let mut m = ModelConfig::preset(ModelPreset::Tiny);
    m.d_model = 32;
    m.d_ff = 64;
    m
}

#[test]
fn manifest_lists_expected_artifacts() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::open(&dir).unwrap();
    for name in [
        "expert_ffn_b64",
        "expert_ffn_b256",
        "expert_ffn_b1024",
        "gated_combine",
        "moe_fwd",
        "init_params",
        "train_step",
    ] {
        assert!(rt.manifest.entries.contains_key(name), "missing artifact {name}");
    }
    assert_eq!(rt.platform().to_lowercase(), "cpu");
}

#[test]
fn pallas_expert_ffn_matches_native() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::open(&dir).unwrap();
    let pjrt = PjrtCompute::new(&rt).unwrap();
    assert_eq!(pjrt.name(), "pjrt");

    let model = tiny_model();
    let mut rng = Rng::new(1);
    let layer = MoeLayer::random(&model, &mut rng);

    // Several row counts exercising padding + bucket selection.
    for rows in [1usize, 5, 64, 100, 256, 300, 1500] {
        let x = Mat::randn(rows, model.d_model, 0.5, &mut rng);
        let want = ffn_forward(&x, &layer.experts[0]);
        let got = pjrt.ffn(&x, &layer.experts[0]);
        assert_eq!(got.rows, rows);
        let diff = got.rel_diff(&want);
        assert!(diff < 1e-5, "rows={rows}: pallas vs native rel diff {diff}");
    }
}

#[test]
fn htiled_kernel_artifact_matches_native() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::open(&dir).unwrap();
    if !rt.manifest.entries.contains_key("expert_ffn_htiled_b256") {
        eprintln!("SKIP: htiled artifact not present (older artifacts) — re-run make artifacts");
        return;
    }
    let model = tiny_model();
    let mut rng = Rng::new(17);
    let layer = MoeLayer::random(&model, &mut rng);
    let x = Mat::randn(256, model.d_model, 0.5, &mut rng);
    let w = &layer.experts[0];
    let out = rt
        .execute_f32(
            "expert_ffn_htiled_b256",
            &[
                (&x.data, &[256, model.d_model as i64]),
                (&w.w_gate.data, &[model.d_model as i64, model.d_ff as i64]),
                (&w.w_up.data, &[model.d_model as i64, model.d_ff as i64]),
                (&w.w_down.data, &[model.d_ff as i64, model.d_model as i64]),
            ],
        )
        .unwrap();
    let got = Mat::from_vec(256, model.d_model, out[0].clone());
    let want = ffn_forward(&x, w);
    let diff = got.rel_diff(&want);
    assert!(diff < 1e-5, "htiled vs native rel diff {diff}");
}

#[test]
fn llep_step_on_pjrt_backend_is_exact() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::open(&dir).unwrap();
    let pjrt = PjrtCompute::new(&rt).unwrap();

    let model = tiny_model();
    let system = SystemConfig::preset(SystemPreset::CpuSim4);
    let engine = Engine::modeled(model.clone(), system);
    let mut rng = Rng::new(2);
    let layer = MoeLayer::random(&model, &mut rng);
    let routing = llep::routing::Scenario::concentrated(0.9, 1).generate(&model, 4, 24, &mut rng);
    let xs: Vec<Mat> = (0..4)
        .map(|p| Mat::randn(routing.tokens_on(p), model.d_model, 0.5, &mut rng))
        .collect();

    let reference = forward_reference(&layer, &xs, &routing);
    let kind = PlannerKind::Llep(LlepConfig { alpha: 1.0, min_gemm_tokens: 2, lambda: 1.0 });
    let step = run_step_real(&engine, &layer, &xs, &routing, &kind, &pjrt).unwrap();
    let native = run_step_real(&engine, &layer, &xs, &routing, &kind, &NativeCompute).unwrap();

    let max_diff = |a: &[Mat], b: &[Mat]| {
        a.iter()
            .zip(b)
            .flat_map(|(x, y)| x.data.iter().zip(&y.data).map(|(u, v)| (u - v).abs()))
            .fold(0f32, f32::max)
    };
    assert!(max_diff(&reference, &step.outputs) < 1e-4, "pjrt vs reference");
    assert!(max_diff(&native.outputs, &step.outputs) < 1e-4, "pjrt vs native engine");
}

#[test]
fn moe_fwd_artifact_cross_checks_engine_routing() {
    // The full JAX MoE layer (router + experts, Pallas path) must agree
    // with the rust engine executing the routing the artifact reports.
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::open(&dir).unwrap();

    let model = tiny_model();
    let tokens = rt.manifest.meta_usize("moe_fwd", "tokens").unwrap();
    let n = rt.manifest.meta_usize("moe_fwd", "num_experts").unwrap();
    let k = rt.manifest.meta_usize("moe_fwd", "top_k").unwrap();
    assert_eq!(n, model.num_experts);
    let (d, h) = (model.d_model, model.d_ff);

    let mut rng = Rng::new(3);
    let layer = MoeLayer::random(&model, &mut rng);
    let x = Mat::randn(tokens, d, 0.5, &mut rng);

    // Stack expert weights (N, D, H) etc. in expert order.
    let stack = |get: &dyn Fn(usize) -> Vec<f32>| -> Vec<f32> {
        (0..n).flat_map(|e| get(e)).collect()
    };
    let wg = stack(&|e| layer.experts[e].w_gate.data.clone());
    let wu = stack(&|e| layer.experts[e].w_up.data.clone());
    let wd = stack(&|e| layer.experts[e].w_down.data.clone());

    let outputs = rt
        .execute_f32(
            "moe_fwd",
            &[
                (&x.data, &[tokens as i64, d as i64]),
                (&layer.router.data, &[d as i64, n as i64]),
                (&wg, &[n as i64, d as i64, h as i64]),
                (&wu, &[n as i64, d as i64, h as i64]),
                (&wd, &[n as i64, h as i64, d as i64]),
            ],
        )
        .unwrap();
    let jax_out = Mat::from_vec(tokens, d, outputs[0].clone());
    let gates = &outputs[1];
    let idx = &outputs[2];
    let counts = &outputs[3];

    // Rebuild the routing the JAX layer used and run the rust engine on it.
    let routing = Routing {
        num_experts: n,
        top_k: k,
        experts: vec![idx.iter().map(|&e| e as u32).collect()],
        gates: vec![gates.clone()],
    };
    routing.validate().unwrap();
    let total: f32 = counts.iter().sum();
    assert_eq!(total as usize, tokens * k, "counts artifact output");

    let system = SystemConfig::preset(SystemPreset::CpuSim4).with_devices(1);
    let engine = Engine::modeled(model.clone(), system);
    let step =
        run_step_real(&engine, &layer, &[x], &routing, &PlannerKind::StandardEp, &NativeCompute)
            .unwrap();
    let diff = step.outputs[0].rel_diff(&jax_out);
    assert!(diff < 1e-4, "jax moe_fwd vs rust engine rel diff {diff}");
}

#[test]
fn trainer_loss_decreases_and_params_update() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::open(&dir).unwrap();
    let mut trainer = llep::trainer::Trainer::new(&rt, 0.0).unwrap();
    let mut rng = Rng::new(4);

    let before_params = trainer.params.clone();
    let mut losses = Vec::new();
    for _ in 0..25 {
        let (x, y) = trainer.make_batch(&mut rng);
        let out = trainer.step(&x, &y).unwrap();
        assert_eq!(out.expert_counts.len(), trainer.num_experts);
        losses.push(out.loss);
    }
    assert_ne!(before_params, trainer.params, "params must update");
    let first = losses[..5].iter().sum::<f32>() / 5.0;
    let last = losses[losses.len() - 5..].iter().sum::<f32>() / 5.0;
    assert!(last < first, "loss should trend down: {first} -> {last}");
    assert!(losses.iter().all(|l| l.is_finite()));
}

#[test]
fn gated_combine_artifact_matches_rust() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::open(&dir).unwrap();
    let tokens = rt.manifest.meta_usize("gated_combine", "tokens").unwrap();
    let k = rt.manifest.meta_usize("gated_combine", "top_k").unwrap();
    let d = 32usize;
    let mut rng = Rng::new(5);
    let y: Vec<f32> = (0..tokens * k * d).map(|_| rng.f32() - 0.5).collect();
    let gates: Vec<f32> = (0..tokens * k).map(|_| rng.f32()).collect();
    let out = rt
        .execute_f32(
            "gated_combine",
            &[
                (&y, &[tokens as i64, k as i64, d as i64]),
                (&gates, &[tokens as i64, k as i64]),
            ],
        )
        .unwrap();
    // rust-side reference
    for t in 0..tokens {
        for c in 0..d {
            let mut want = 0f32;
            for s in 0..k {
                want += gates[t * k + s] * y[(t * k + s) * d + c];
            }
            let got = out[0][t * d + c];
            assert!((got - want).abs() < 1e-4, "t={t} c={c}: {got} vs {want}");
        }
    }
}
