//! Metamorphic/invariant tests over the modeled engine — the properties
//! the paper's analysis (§3.2, §5.3) predicts must hold for ANY
//! workload, checked across randomized scenarios.

use llep::config::{LlepConfig, ModelConfig, ModelPreset, SystemConfig, SystemPreset};
use llep::exec::Engine;
use llep::planner::PlannerKind;
use llep::routing::{LoadMatrix, Scenario};
use llep::util::prop::{assert_property, no_shrink};
use llep::util::rng::Rng;

fn engine() -> Engine {
    Engine::modeled(
        ModelConfig::preset(ModelPreset::Fig1Layer),
        SystemConfig::preset(SystemPreset::H200x8),
    )
}

#[derive(Clone, Debug)]
struct Workload {
    concentration: f64,
    hot: usize,
    tokens: usize,
    seed: u64,
}

fn gen_workload(rng: &mut Rng) -> Workload {
    Workload {
        concentration: rng.f64(),
        hot: [1usize, 4, 16][rng.index(3)],
        tokens: [2048usize, 8192, 32_768][rng.index(3)],
        seed: rng.next_u64(),
    }
}

fn loads_for(w: &Workload, e: &Engine) -> LoadMatrix {
    Scenario::concentrated(w.concentration, w.hot).generate_loads(
        &e.model,
        e.system.devices,
        w.tokens,
        &mut Rng::new(w.seed),
    )
}

/// LLEP must never be meaningfully slower than EP (the lambda guard
/// guarantees parity when balanced; LLA wins when imbalanced).
#[test]
fn llep_never_slower_than_ep() {
    let e = engine();
    assert_property(
        "llep <= ep latency",
        1,
        60,
        gen_workload,
        |w| {
            let lm = loads_for(w, &e);
            let ep = e.run_step_loads(&lm, &PlannerKind::StandardEp);
            let ll = e.run_step_loads(&lm, &PlannerKind::llep_default());
            // 5% slack for measured plan time jitter
            if ll.latency_s <= ep.latency_s * 1.05 {
                Ok(())
            } else {
                Err(format!("LLEP {} vs EP {}", ll.latency_s, ep.latency_s))
            }
        },
        no_shrink,
    );
}

/// LLEP's peak memory is *stable*: bounded by the balanced baseline plus
/// a few imported expert weights, regardless of imbalance (paper Fig. 1b
/// "near-constant memory"). At mild imbalance imports can put it a hair
/// above EP; it must never blow up the way EP does.
#[test]
fn llep_memory_is_stable() {
    let e = engine();
    // balanced-baseline peak at each batch size
    let balanced_peak = |tokens: usize| {
        let lm = Scenario::balanced().generate_loads(&e.model, 8, tokens, &mut Rng::new(7));
        e.run_step_loads(&lm, &PlannerKind::StandardEp).max_peak_bytes()
    };
    assert_property(
        "llep mem stable",
        2,
        60,
        gen_workload,
        |w| {
            let lm = loads_for(w, &e);
            let ep = e.run_step_loads(&lm, &PlannerKind::StandardEp);
            let ll = e.run_step_loads(&lm, &PlannerKind::llep_default());
            // stable bound: balanced peak + 25% activation headroom +
            // imported expert weights (a device can import at most ~P
            // hot experts' weights in practice)
            let import_headroom = 8 * e.model.expert_weight_bytes() as u64;
            let bound = (balanced_peak(w.tokens) as f64 * 1.25) as u64 + import_headroom;
            if ll.max_peak_bytes() > bound {
                return Err(format!(
                    "LLEP peak {} exceeds stable bound {bound}",
                    ll.max_peak_bytes()
                ));
            }
            // and never more than a whisker above EP
            if ll.max_peak_bytes() as f64 > ep.max_peak_bytes() as f64 * 1.15 {
                return Err(format!(
                    "LLEP {} far above EP {}",
                    ll.max_peak_bytes(),
                    ep.max_peak_bytes()
                ));
            }
            Ok(())
        },
        no_shrink,
    );
}

/// EP latency is monotone in concentration (paper Fig. 1a's x-axis).
#[test]
fn ep_latency_monotone_in_concentration() {
    let e = engine();
    let mut rng = Rng::new(3);
    let mut last = 0.0;
    for &c in &[0.0f64, 0.3, 0.5, 0.8, 0.95] {
        let lm =
            Scenario::concentrated(c.max(0.01), 1).generate_loads(&e.model, 8, 16_384, &mut rng);
        let r = e.run_step_loads(&lm, &PlannerKind::StandardEp);
        assert!(
            r.latency_s >= last * 0.999,
            "latency dropped at c={c}: {} < {last}",
            r.latency_s
        );
        last = r.latency_s;
    }
}

/// Alpha monotonicity (paper Fig. 6b): smaller alpha -> tighter balance
/// -> compute span never worse.
#[test]
fn smaller_alpha_tighter_balance() {
    let e = engine();
    let mut rng = Rng::new(4);
    let lm = Scenario::concentrated(0.9, 4).generate_loads(&e.model, 8, 32_768, &mut rng);
    let mut last_imbalance = 0.0;
    for &alpha in &[1.0, 1.5, 2.0, 3.0] {
        let kind = PlannerKind::Llep(LlepConfig::default().with_alpha(alpha).with_lambda(1.0));
        let r = e.run_step_loads(&lm, &kind);
        assert!(
            r.compute_imbalance() >= last_imbalance * 0.999,
            "alpha={alpha}: imbalance {} < previous {last_imbalance}",
            r.compute_imbalance()
        );
        last_imbalance = r.compute_imbalance();
    }
}

/// Eq.-4 memory accounting: recompute by hand from the plan.
#[test]
fn memory_matches_eq4_by_hand() {
    let e = engine();
    let mut rng = Rng::new(5);
    let lm = Scenario::concentrated(0.8, 4).generate_loads(&e.model, 8, 8192, &mut rng);
    let r = e.run_step_loads(&lm, &PlannerKind::llep_default());
    let loads = lm.expert_loads();
    let plan = PlannerKind::llep_default().plan(8, &loads, Some(&e.topo));
    let m = e.model.num_experts / 8;
    let (d, h, bytes) = (e.model.d_model as u64, e.model.d_ff as u64, e.model.dtype_bytes as u64);
    for dev in 0..8 {
        let work_tokens: u64 = plan.work_on(dev).iter().map(|(_, s)| s.len()).sum();
        let imports = plan.imports_to(dev).len() as u64;
        let want = (m as u64 + imports) * 3 * d * h * bytes + work_tokens * (d + h) * bytes;
        assert_eq!(r.device_peak_bytes[dev], want, "device {dev}");
    }
}

/// EPLB with perfectly fresh statistics cannot be worse than EP; with
/// adversarially stale statistics it can be much worse than LLEP.
#[test]
fn eplb_fresh_vs_stale() {
    let e = engine();
    let mut rng = Rng::new(6);
    let lm_hot = Scenario::concentrated(0.9, 1).generate_loads(&e.model, 8, 16_384, &mut rng);
    let fresh = e.run_step_loads(&lm_hot, &PlannerKind::Eplb { replicas: 8 });
    let ep = e.run_step_loads(&lm_hot, &PlannerKind::StandardEp);
    assert!(fresh.latency_s <= ep.latency_s);

    // stale: stats say the hotspot is elsewhere
    let mut cold_counts = lm_hot.clone();
    for row in cold_counts.counts.iter_mut() {
        row.rotate_right(e.model.num_experts / 2);
    }
    let stale =
        e.run_step_loads_with_stats(&lm_hot, &cold_counts, &PlannerKind::Eplb { replicas: 8 });
    let llep = e.run_step_loads(&lm_hot, &PlannerKind::llep_default());
    assert!(
        stale.latency_s > llep.latency_s,
        "stale EPLB {} should lose to LLEP {}",
        stale.latency_s,
        llep.latency_s
    );
}

/// Scaling devices down must still work (P=2..16) and conserve tokens.
#[test]
fn device_count_sweep() {
    for p in [2usize, 4, 8, 16] {
        let model = ModelConfig::preset(ModelPreset::Fig1Layer); // 128 experts
        let system = SystemConfig::preset(SystemPreset::H200x8).with_devices(p);
        let e = Engine::modeled(model.clone(), system);
        let mut rng = Rng::new(p as u64);
        let lm = Scenario::concentrated(0.9, 2).generate_loads(&model, p, 4096, &mut rng);
        let r = e.run_step_loads(&lm, &PlannerKind::llep_default());
        assert_eq!(r.tokens, (p * 4096) as u64);
        assert_eq!(r.device_compute_s.len(), p);
        assert!(!r.oom);
    }
}

/// Zero-load (empty batch) step must not panic and must cost ~nothing.
#[test]
fn empty_batch_step() {
    let e = engine();
    let lm = LoadMatrix { counts: vec![vec![0; 128]; 8], top_k: 4 };
    for kind in [PlannerKind::StandardEp, PlannerKind::llep_default()] {
        let r = e.run_step_loads(&lm, &kind);
        assert_eq!(r.tokens, 0);
        assert_eq!(r.bytes_dispatch, 0);
        assert_eq!(r.gemm_calls, 0);
    }
}
