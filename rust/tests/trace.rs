//! Observability integration tests: the Chrome trace-event exporter
//! over real engine/serve/fleet runs, the paper's timeline claim
//! asserted on recorded span durations, and JSON round-trips of the
//! versioned report exporters.

use llep::config::{ModelConfig, ModelPreset, SystemConfig, SystemPreset};
use llep::coordinator::{ChaosStats, ServeSim};
use llep::exec::Engine;
use llep::fleet::{FleetSim, ReplicaConfig, RouterPolicy, Workload};
use llep::metrics::{chaos_stats_to_json, fleet_report_to_json, SCHEMA_VERSION};
use llep::planner::PlannerKind;
use llep::routing::Scenario;
use llep::trace::Tracer;
use llep::util::json::{parse, Json};
use llep::util::rng::Rng;

fn engine() -> Engine {
    Engine::modeled(
        ModelConfig::preset(ModelPreset::Fig1Layer),
        SystemConfig::preset(SystemPreset::H200x8),
    )
}

/// Export the sink and re-parse it through the crate's own JSON parser,
/// so every assertion below runs against what a viewer would actually
/// load.
fn exported_events(tracer: &Tracer) -> (Json, Vec<Json>) {
    let doc = parse(&tracer.export().unwrap().to_string()).unwrap();
    let events = doc.get("traceEvents").unwrap().as_arr().unwrap().to_vec();
    assert!(!events.is_empty());
    (doc, events)
}

fn ph<'a>(e: &'a Json) -> &'a str {
    e.get("ph").unwrap().as_str().unwrap()
}

fn name<'a>(e: &'a Json) -> &'a str {
    e.get("name").unwrap().as_str().unwrap()
}

/// Max duration (µs) over `name`d complete spans recorded under `pid`.
fn max_span_dur(events: &[Json], pid: f64, span_name: &str) -> f64 {
    events
        .iter()
        .filter(|e| ph(e) == "X" && name(e) == span_name)
        .filter(|e| e.get("pid").unwrap().as_f64() == Some(pid))
        .map(|e| e.get("dur").unwrap().as_f64().unwrap())
        .fold(0.0, f64::max)
}

/// The tentpole acceptance: tracing an EP step and an LLEP step of the
/// same heavily-skewed workload side by side (two Chrome pids, one
/// sink), EP's longest device-compute span visibly exceeds LLEP's —
/// the straggler bubble the paper's figures draw, now asserted on the
/// recorded timeline itself.
#[test]
fn ep_vs_llep_trace_shows_the_straggler_bubble() {
    let tracer = Tracer::enabled();
    let base = engine();
    let ep = base.clone().with_tracer(tracer.with_pid(0));
    let ll = base.clone().with_tracer(tracer.with_pid(1));
    llep::trace::name_engine_tracks(&ep.tracer, "standard EP", base.system.devices);
    llep::trace::name_engine_tracks(&ll.tracer, "LLEP", base.system.devices);

    let mut rng = Rng::new(0);
    let lm = Scenario::concentrated(0.95, 1).generate_loads(&base.model, 8, 32_768, &mut rng);
    let ep_report = ep.run_step_loads(&lm, &PlannerKind::StandardEp);
    let ll_report = ll.run_step_loads(&lm, &PlannerKind::llep_default());
    assert!(ll_report.latency_s < ep_report.latency_s);

    let (doc, events) = exported_events(&tracer);

    // Well-formed Chrome events: every entry names a phase and a pid.
    for e in &events {
        assert!(e.get("pid").is_some() && e.get("name").is_some(), "{e:?}");
        if ph(e) == "X" {
            assert!(e.get("dur").unwrap().as_f64().unwrap() >= 0.0);
            assert!(e.get("ts").unwrap().as_f64().unwrap() >= 0.0);
        }
    }
    // Non-empty slice and flow arrays (LLEP's weight rebalancing is the
    // flow source; EP never transfers weights).
    assert!(events.iter().any(|e| ph(e) == "X"));
    let starts: Vec<&Json> = events.iter().filter(|e| ph(e) == "s").collect();
    let ends: Vec<&Json> = events.iter().filter(|e| ph(e) == "f").collect();
    assert!(!starts.is_empty(), "LLEP on a skewed step must record weight-transfer flows");
    assert_eq!(starts.len(), ends.len(), "every flow arrow has both endpoints");

    // The timeline claim, on span durations.
    let ep_max = max_span_dur(&events, 0.0, "compute");
    let ll_max = max_span_dur(&events, 1.0, "compute");
    assert!(ep_max > 0.0 && ll_max > 0.0);
    assert!(
        ep_max > 1.5 * ll_max,
        "EP max compute span {ep_max} µs should visibly exceed LLEP's {ll_max} µs"
    );

    // The metrics registry rides the same document.
    let metrics = doc.get("llepMetrics").unwrap();
    assert_eq!(
        metrics.get("counters").unwrap().get("engine/steps").unwrap().as_usize(),
        Some(2)
    );
    let hist = metrics.get("histograms").unwrap().get("step/imbalance_ratio").unwrap();
    assert_eq!(hist.get("count").unwrap().as_usize(), Some(2));
}

/// A traced serving run records coordinator-track serve events on the
/// virtual clock, and `Tracer::write` produces a loadable file (while
/// an unwritable path errors — the CLI's non-zero-exit contract).
#[test]
fn serve_trace_records_steps_and_writes_file() {
    let tracer = Tracer::enabled();
    let eng = engine().with_tracer(tracer.with_pid(0));
    llep::trace::name_engine_tracks(&eng.tracer, "llep serve", eng.system.devices);
    let mut rng = Rng::new(0);
    let requests = ServeSim::poisson_requests(8, 0.0005, 256, 2048, &mut rng);
    let sim = ServeSim::with_planner(
        eng,
        PlannerKind::llep_default().boxed(),
        Scenario::concentrated(0.8, 4),
        8192,
    );
    let r = sim.try_run(&requests, &mut Rng::new(1)).unwrap();

    let (doc, events) = exported_events(&tracer);
    assert!(events.iter().any(|e| ph(e) == "X" && name(e) == "serve-step"));
    assert!(events.iter().any(|e| ph(e) == "i" && name(e) == "admit"));
    assert!(events.iter().any(|e| ph(e) == "i" && name(e) == "request-finished"));
    let counters = doc.get("llepMetrics").unwrap().get("counters").unwrap();
    assert_eq!(counters.get("serve/finished").unwrap().as_usize(), Some(r.completed));

    let path = std::env::temp_dir().join("llep_trace_serve_test.json");
    let path = path.to_str().unwrap();
    tracer.write(path).unwrap();
    let reread = parse(&std::fs::read_to_string(path).unwrap()).unwrap();
    assert!(!reread.get("traceEvents").unwrap().as_arr().unwrap().is_empty());
    let _ = std::fs::remove_file(path);

    assert!(tracer.write("/nonexistent-llep-dir/trace.json").is_err());
}

/// A traced fleet run: replicas appear as separate Chrome processes,
/// and every router decision records as a flow arrow from the frontend
/// workload track to the chosen replica.
#[test]
fn fleet_trace_records_router_flows_and_replica_processes() {
    let tracer = Tracer::enabled();
    let template = engine().with_tracer(tracer.clone());
    let sim = FleetSim::new(
        template,
        Scenario::concentrated(0.8, 4),
        vec![ReplicaConfig::default(); 2],
        16_384,
    )
    // Round-robin guarantees both replicas receive work, so the
    // per-replica compute-span assertions below are deterministic.
    .with_router(RouterPolicy::parse("round-robin").unwrap())
    .with_workload(
        Workload::parse("poisson:n=8,ia=0.0005,prompt=128-512,decode=2-6").unwrap(),
    );
    let r = sim.try_run(3).unwrap();
    assert_eq!(r.completed, 8);

    let (doc, events) = exported_events(&tracer);
    let route_starts: Vec<&Json> =
        events.iter().filter(|e| ph(e) == "s" && name(e) == "route").collect();
    assert_eq!(route_starts.len(), r.requests, "one routing flow per arrival");
    // Flow arrows start on the frontend process (pid 0) and land on a
    // replica process (pid >= 1).
    for s in &route_starts {
        assert_eq!(s.get("pid").unwrap().as_usize(), Some(0));
    }
    assert!(events
        .iter()
        .any(|e| ph(e) == "f" && name(e) == "route" && e.get("pid").unwrap().as_f64() != Some(0.0)));
    // Replica engines emit compute spans under their own pids.
    assert!(max_span_dur(&events, 1.0, "compute") > 0.0);
    assert!(max_span_dur(&events, 2.0, "compute") > 0.0);
    // Process metadata names the frontend and both replicas.
    let proc_names: Vec<&str> = events
        .iter()
        .filter(|e| ph(e) == "M" && name(e) == "process_name")
        .map(|e| e.get("args").unwrap().get("name").unwrap().as_str().unwrap())
        .collect();
    assert!(proc_names.iter().any(|n| n.contains("frontend")), "{proc_names:?}");
    assert!(proc_names.iter().any(|n| n.contains("replica 0")), "{proc_names:?}");
    assert!(proc_names.iter().any(|n| n.contains("replica 1")), "{proc_names:?}");
    let counters = doc.get("llepMetrics").unwrap().get("counters").unwrap();
    assert_eq!(counters.get("router/arrivals").unwrap().as_usize(), Some(r.requests));
}

/// Satellite: the fleet report JSON round-trips through the crate's own
/// parser — schema version, ledger totals and per-replica plan-cache
/// counters (including `cache_repairs`) all survive.
#[test]
fn fleet_report_json_round_trips() {
    let sim = FleetSim::new(
        engine(),
        Scenario::concentrated(0.8, 4),
        vec![ReplicaConfig::default(); 2],
        16_384,
    )
    .with_workload(
        Workload::parse("poisson:n=8,ia=0.0005,prompt=128-512,decode=2-6").unwrap(),
    );
    let mut r = sim.try_run(3).unwrap();
    // Plant distinctive non-zero cache counters so "survives the
    // round-trip" is meaningful even when the run itself had none.
    r.replicas[0].plan_cache.hits = 11;
    r.replicas[0].plan_cache.repairs = 7;
    r.replicas[0].plan_cache.misses = 3;
    r.replicas[0].plan_cache.forced = 2;

    let re = parse(&fleet_report_to_json(&r).to_string()).unwrap();
    assert_eq!(re.get("schema_version").unwrap().as_usize(), Some(SCHEMA_VERSION as usize));
    assert_eq!(
        re.get("tokens_admitted").unwrap().as_f64(),
        Some(r.tokens.admitted as f64)
    );
    assert_eq!(re.get("tokens_priced").unwrap().as_f64(), Some(r.tokens.priced as f64));
    assert_eq!(re.get("ledger_exact").unwrap().as_bool(), Some(true));
    assert_eq!(re.get("completed").unwrap().as_usize(), Some(r.completed));

    let reps = re.get("replicas").unwrap().as_arr().unwrap();
    assert_eq!(reps.len(), 2);
    assert_eq!(reps[0].get("cache_hits").unwrap().as_usize(), Some(11));
    assert_eq!(reps[0].get("cache_repairs").unwrap().as_usize(), Some(7));
    assert_eq!(reps[0].get("cache_misses").unwrap().as_usize(), Some(3));
    assert_eq!(reps[0].get("cache_forced").unwrap().as_usize(), Some(2));
    for (i, (j, p)) in reps.iter().zip(&r.replicas).enumerate() {
        assert_eq!(
            j.get("tokens_admitted").unwrap().as_f64(),
            Some(p.tokens.admitted as f64),
            "replica {i}"
        );
        assert_eq!(j.get("chaos").unwrap().get("requeues").unwrap().as_usize(), Some(0));
    }
}

/// Satellite: chaos accounting round-trips exactly, field by field.
#[test]
fn chaos_stats_json_round_trips() {
    let c = ChaosStats {
        fault_steps: 5,
        failures: 2,
        recoveries: 1,
        requeues: 3,
        requeued_tokens: 4096,
        wasted_s: 0.125,
        max_recovery_steps: 4,
    };
    let re = parse(&chaos_stats_to_json(&c).to_string()).unwrap();
    assert_eq!(re.get("fault_steps").unwrap().as_usize(), Some(5));
    assert_eq!(re.get("failures").unwrap().as_usize(), Some(2));
    assert_eq!(re.get("recoveries").unwrap().as_usize(), Some(1));
    assert_eq!(re.get("requeues").unwrap().as_usize(), Some(3));
    assert_eq!(re.get("requeued_tokens").unwrap().as_usize(), Some(4096));
    assert_eq!(re.get("wasted_s").unwrap().as_f64(), Some(0.125));
    assert_eq!(re.get("max_recovery_steps").unwrap().as_usize(), Some(4));
}
