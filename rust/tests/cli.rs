//! CLI integration tests: drive the `llep` binary end-to-end via
//! std::process and assert on its output (figures, run, trace/replay,
//! config loading, error handling).

use std::path::PathBuf;
use std::process::Command;

fn llep() -> Command {
    let bin = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("target")
        .join(if cfg!(debug_assertions) { "debug" } else { "release" })
        .join("llep");
    Command::new(bin)
}

fn run_ok(args: &[&str]) -> String {
    let out = llep().args(args).output().expect("spawn llep");
    assert!(
        out.status.success(),
        "llep {args:?} failed:\nstdout: {}\nstderr: {}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8_lossy(&out.stdout).into_owned()
}

#[test]
fn info_lists_presets() {
    let out = run_ok(&["info"]);
    for name in ["gpt-oss-120b", "deepseek-v3", "kimi-k2", "h200x8", "h100x8", "cpusim8"] {
        assert!(out.contains(name), "info missing {name}:\n{out}");
    }
    assert!(out.contains("tunable:"), "info marks tunable planner parameters:\n{out}");
}

#[test]
fn figures_1a_has_all_scenarios() {
    let out = run_ok(&["figures", "--fig", "1a"]);
    for label in ["balanced", "30% into 16", "95% into 1", "speedup"] {
        assert!(out.contains(label), "fig 1a missing {label}");
    }
}

#[test]
fn run_compares_three_planners() {
    let out = run_ok(&[
        "run",
        "--model",
        "fig1-layer",
        "--scenario",
        "concentrated",
        "--concentration",
        "0.9",
        "--hot",
        "1",
        "--tokens",
        "8192",
    ]);
    assert!(out.contains("EP"));
    assert!(out.contains("LLEP"));
    assert!(out.contains("EPLB"));
}

#[test]
fn run_full_model_prices_all_layers() {
    let out = run_ok(&[
        "run",
        "--model",
        "gpt-oss-20b",
        "--full-model",
        "--layers",
        "6",
        "--scenario",
        "drift",
        "--tokens",
        "4096",
    ]);
    assert!(out.contains("full model, 6 MoE layers"), "{out}");
    assert!(out.contains("overlap saved"), "{out}");
    assert!(out.contains("per-layer breakdown"), "{out}");
    assert!(out.contains("LLEP"), "default comparison includes LLEP:\n{out}");
    assert!(out.contains("L5"), "per-layer rows present:\n{out}");
}

#[test]
fn run_loads_config_file() {
    let cfg = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("configs/fig1.toml");
    let out = run_ok(&["run", "--config", cfg.to_str().unwrap()]);
    assert!(out.contains("fig1-layer"));
    assert!(out.contains("95% into 1"));
}

#[test]
fn trace_then_replay_roundtrip() {
    let dir = std::env::temp_dir().join("llep_cli_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("trace.json");
    let path_s = path.to_str().unwrap();
    run_ok(&[
        "trace", "--out", path_s, "--batches", "4", "--tokens", "2048",
        "--scenario", "drift", "--hot", "11",
    ]);
    assert!(path.exists());
    let out = run_ok(&["replay", "--trace", path_s]);
    assert!(out.contains("4 batches"));
    assert!(out.contains("LLEP"));
    std::fs::remove_file(path).ok();
}

#[test]
fn serve_reports_latency_percentiles() {
    let out = run_ok(&["serve", "--steps", "16"]);
    assert!(out.contains("p50 latency"));
    assert!(out.contains("tok/s"));
    assert!(out.contains("plan cache"), "serve table lists cache column:\n{out}");
}

#[test]
fn run_accepts_planner_spec() {
    let out = run_ok(&[
        "run", "--planner", "lpt:min=512", "--scenario", "concentrated", "--tokens", "4096",
    ]);
    assert!(out.contains("LPT(min=512)"), "{out}");
    assert!(!out.contains("EPLB"), "--planner overrides the default comparison set:\n{out}");
}

#[test]
fn serve_with_plan_reuse_reports_cache_hits() {
    let out = run_ok(&[
        "serve", "--steps", "12", "--planner", "llep", "--plan-reuse", "--replan-every", "8",
        "--cache-drift", "0.2",
    ]);
    assert!(out.contains("Cached[LLEP"), "{out}");
    assert!(out.contains("%"), "hit-rate column rendered:\n{out}");
}

#[test]
fn explicit_cached_spec_runs_and_rejects_conflicting_flags() {
    // An explicit cached(...) spec works on its own ...
    let out = run_ok(&["serve", "--steps", "8", "--planner", "cached(llep):drift=0.1"]);
    assert!(out.contains("Cached[LLEP"), "{out}");
    assert!(!out.contains("Cached[Cached"), "{out}");

    // ... but combining it with the cache flags would silently change the
    // experiment, so it must fail loudly instead.
    let args =
        ["serve", "--steps", "8", "--planner", "cached(llep):drift=0.1", "--replan-every", "4"];
    let out = llep().args(args).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("already-cached"));
}

#[test]
fn bad_planner_spec_fails_loudly() {
    let out = llep().args(["run", "--planner", "bogus"]).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown planner"));

    let out = llep().args(["run", "--planner", "llep:frob=1"]).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown parameter"));
}

#[test]
fn info_lists_planner_registry() {
    let out = run_ok(&["info"]);
    for name in ["ep", "llep", "eplb", "chunked", "lpt", "cached"] {
        assert!(out.contains(name), "info missing planner {name}:\n{out}");
    }
}

#[test]
fn tune_smoke_emits_front_and_verified_recommendation() {
    let out = run_ok(&[
        "tune", "--budget", "smoke", "--profile", "cpusim4", "--scenario", "concentrated",
        "--tokens", "1024",
    ]);
    assert!(out.contains("Pareto front"), "{out}");
    assert!(out.contains("recommended: --planner"), "{out}");
    assert!(out.contains("re-evaluated bit-identically: true"), "{out}");
    assert!(out.contains("budget units priced"), "{out}");
}

#[test]
fn tune_recommended_spec_feeds_back_into_run() {
    // The round-trip the subsystem promises: the recommended spec is a
    // valid --planner argument for the other subcommands.
    let out = run_ok(&[
        "tune", "--budget", "smoke", "--profile", "cpusim4", "--scenario", "concentrated",
        "--tokens", "1024", "--strategy", "halving",
    ]);
    let spec = out
        .lines()
        .find_map(|l| l.strip_prefix("recommended: --planner "))
        .expect("tune prints a recommendation")
        .trim()
        .to_string();
    let run_out = run_ok(&["run", "--planner", &spec, "--tokens", "2048"]);
    assert!(!run_out.is_empty());
}

#[test]
fn tune_rejects_unknown_profile_budget_and_mode() {
    for args in [
        ["tune", "--profile", "tpu9000"],
        ["tune", "--budget", "enormous"],
        ["tune", "--mode", "training"],
    ] {
        let out = llep().args(args).output().unwrap();
        assert!(!out.status.success(), "{args:?} must fail");
        assert!(
            String::from_utf8_lossy(&out.stderr).contains("unknown"),
            "{args:?}: {}",
            String::from_utf8_lossy(&out.stderr)
        );
    }
}

#[test]
fn tune_writes_json_report() {
    let dir = std::env::temp_dir().join("llep_tune_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("tune.json");
    run_ok(&[
        "tune", "--budget", "smoke", "--profile", "cpusim4", "--scenario", "powerlaw",
        "--tokens", "1024", "--out", path.to_str().unwrap(),
    ]);
    let text = std::fs::read_to_string(&path).unwrap();
    for key in ["\"front\"", "\"recommended\"", "\"trials\"", "\"profile\""] {
        assert!(text.contains(key), "JSON report missing {key}:\n{text}");
    }
    std::fs::remove_file(path).ok();
}

#[test]
fn calibrate_fits_model() {
    let out = run_ok(&["calibrate"]);
    assert!(out.contains("peak_flops"));
    assert!(out.contains("overhead_s"));
}

#[test]
fn unknown_flag_and_subcommand_fail_loudly() {
    let out = llep().args(["figures", "--bogus"]).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown option"));

    let out = llep().args(["frobnicate"]).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown subcommand"));
}

#[test]
fn help_prints_usage() {
    let out = run_ok(&["--help"]);
    assert!(out.contains("usage: llep"));
    assert!(out.contains("--fig"));
}
