//! CLI integration tests: drive the `llep` binary end-to-end via
//! std::process and assert on its output (figures, run, trace/replay,
//! config loading, error handling).

use std::path::PathBuf;
use std::process::Command;

fn llep() -> Command {
    let bin = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("target")
        .join(if cfg!(debug_assertions) { "debug" } else { "release" })
        .join("llep");
    Command::new(bin)
}

fn run_ok(args: &[&str]) -> String {
    let out = llep().args(args).output().expect("spawn llep");
    assert!(
        out.status.success(),
        "llep {args:?} failed:\nstdout: {}\nstderr: {}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8_lossy(&out.stdout).into_owned()
}

#[test]
fn info_lists_presets() {
    let out = run_ok(&["info"]);
    for name in ["gpt-oss-120b", "deepseek-v3", "kimi-k2", "h200x8", "h100x8", "cpusim8"] {
        assert!(out.contains(name), "info missing {name}:\n{out}");
    }
    assert!(out.contains("tunable:"), "info marks tunable planner parameters:\n{out}");
}

#[test]
fn figures_1a_has_all_scenarios() {
    let out = run_ok(&["figures", "--fig", "1a"]);
    for label in ["balanced", "30% into 16", "95% into 1", "speedup"] {
        assert!(out.contains(label), "fig 1a missing {label}");
    }
}

#[test]
fn run_compares_three_planners() {
    let out = run_ok(&[
        "run",
        "--model",
        "fig1-layer",
        "--scenario",
        "concentrated",
        "--concentration",
        "0.9",
        "--hot",
        "1",
        "--tokens",
        "8192",
    ]);
    assert!(out.contains("EP"));
    assert!(out.contains("LLEP"));
    assert!(out.contains("EPLB"));
}

#[test]
fn run_full_model_prices_all_layers() {
    let out = run_ok(&[
        "run",
        "--model",
        "gpt-oss-20b",
        "--full-model",
        "--layers",
        "6",
        "--scenario",
        "drift",
        "--tokens",
        "4096",
    ]);
    assert!(out.contains("full model, 6 MoE layers"), "{out}");
    assert!(out.contains("overlap saved"), "{out}");
    assert!(out.contains("per-layer breakdown"), "{out}");
    assert!(out.contains("LLEP"), "default comparison includes LLEP:\n{out}");
    assert!(out.contains("L5"), "per-layer rows present:\n{out}");
}

#[test]
fn run_loads_config_file() {
    let cfg = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("configs/fig1.toml");
    let out = run_ok(&["run", "--config", cfg.to_str().unwrap()]);
    assert!(out.contains("fig1-layer"));
    assert!(out.contains("95% into 1"));
}

#[test]
fn trace_then_replay_roundtrip() {
    let dir = std::env::temp_dir().join("llep_cli_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("trace.json");
    let path_s = path.to_str().unwrap();
    run_ok(&[
        "trace", "--out", path_s, "--batches", "4", "--tokens", "2048",
        "--scenario", "drift", "--hot", "11",
    ]);
    assert!(path.exists());
    let out = run_ok(&["replay", "--trace", path_s]);
    assert!(out.contains("4 batches"));
    assert!(out.contains("LLEP"));
    std::fs::remove_file(path).ok();
}

#[test]
fn serve_reports_latency_percentiles() {
    let out = run_ok(&["serve", "--steps", "16"]);
    assert!(out.contains("p50 latency"));
    assert!(out.contains("tok/s"));
    assert!(out.contains("plan cache"), "serve table lists cache column:\n{out}");
}

#[test]
fn run_accepts_planner_spec() {
    let out = run_ok(&[
        "run", "--planner", "lpt:min=512", "--scenario", "concentrated", "--tokens", "4096",
    ]);
    assert!(out.contains("LPT(min=512)"), "{out}");
    assert!(!out.contains("EPLB"), "--planner overrides the default comparison set:\n{out}");
}

#[test]
fn serve_with_plan_reuse_reports_cache_hits() {
    let out = run_ok(&[
        "serve", "--steps", "12", "--planner", "llep", "--plan-reuse", "--replan-every", "8",
        "--cache-drift", "0.2",
    ]);
    assert!(out.contains("Cached[LLEP"), "{out}");
    assert!(out.contains("%"), "hit-rate column rendered:\n{out}");
}

#[test]
fn explicit_cached_spec_runs_and_rejects_conflicting_flags() {
    // An explicit cached(...) spec works on its own ...
    let out = run_ok(&["serve", "--steps", "8", "--planner", "cached(llep):drift=0.1"]);
    assert!(out.contains("Cached[LLEP"), "{out}");
    assert!(!out.contains("Cached[Cached"), "{out}");

    // ... but combining it with the cache flags would silently change the
    // experiment, so it must fail loudly instead.
    let args =
        ["serve", "--steps", "8", "--planner", "cached(llep):drift=0.1", "--replan-every", "4"];
    let out = llep().args(args).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("already-cached"));
}

#[test]
fn bad_planner_spec_fails_loudly() {
    let out = llep().args(["run", "--planner", "bogus"]).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown planner"));

    let out = llep().args(["run", "--planner", "llep:frob=1"]).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown parameter"));
}

#[test]
fn info_lists_planner_registry() {
    let out = run_ok(&["info"]);
    for name in ["ep", "llep", "eplb", "chunked", "lpt", "cached"] {
        assert!(out.contains(name), "info missing planner {name}:\n{out}");
    }
}

#[test]
fn tune_smoke_emits_front_and_verified_recommendation() {
    let out = run_ok(&[
        "tune", "--budget", "smoke", "--profile", "cpusim4", "--scenario", "concentrated",
        "--tokens", "1024",
    ]);
    assert!(out.contains("Pareto front"), "{out}");
    assert!(out.contains("recommended: --planner"), "{out}");
    assert!(out.contains("re-evaluated bit-identically: true"), "{out}");
    assert!(out.contains("budget units priced"), "{out}");
}

#[test]
fn tune_recommended_spec_feeds_back_into_run() {
    // The round-trip the subsystem promises: the recommended spec is a
    // valid --planner argument for the other subcommands.
    let out = run_ok(&[
        "tune", "--budget", "smoke", "--profile", "cpusim4", "--scenario", "concentrated",
        "--tokens", "1024", "--strategy", "halving",
    ]);
    let spec = out
        .lines()
        .find_map(|l| l.strip_prefix("recommended: --planner "))
        .expect("tune prints a recommendation")
        .trim()
        .to_string();
    let run_out = run_ok(&["run", "--planner", &spec, "--tokens", "2048"]);
    assert!(!run_out.is_empty());
}

#[test]
fn tune_rejects_unknown_profile_budget_and_mode() {
    for args in [
        ["tune", "--profile", "tpu9000"],
        ["tune", "--budget", "enormous"],
        ["tune", "--mode", "training"],
    ] {
        let out = llep().args(args).output().unwrap();
        assert!(!out.status.success(), "{args:?} must fail");
        assert!(
            String::from_utf8_lossy(&out.stderr).contains("unknown"),
            "{args:?}: {}",
            String::from_utf8_lossy(&out.stderr)
        );
    }
}

#[test]
fn tune_writes_json_report() {
    let dir = std::env::temp_dir().join("llep_tune_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("tune.json");
    run_ok(&[
        "tune", "--budget", "smoke", "--profile", "cpusim4", "--scenario", "powerlaw",
        "--tokens", "1024", "--out", path.to_str().unwrap(),
    ]);
    let text = std::fs::read_to_string(&path).unwrap();
    for key in ["\"front\"", "\"recommended\"", "\"trials\"", "\"profile\""] {
        assert!(text.contains(key), "JSON report missing {key}:\n{text}");
    }
    std::fs::remove_file(path).ok();
}

#[test]
fn chaos_compares_planners_under_a_straggler() {
    let out = run_ok(&["chaos", "--steps", "8", "--faults", "slow:dev=0,x=4"]);
    assert!(out.contains("faults: slow:dev=0,x=4"), "{out}");
    assert!(out.contains("LLEP"), "{out}");
    assert!(out.contains("fault steps"), "{out}");
    assert!(out.contains("ok"), "{out}");
}

#[test]
fn chaos_failure_marks_static_ep_unrecoverable() {
    let out = run_ok(&["chaos", "--steps", "12", "--faults", "fail:dev=0,at=1"]);
    assert!(out.contains("unrecoverable"), "EP cannot adapt:\n{out}");
    assert!(out.contains("ok"), "chaos-aware LLEP recovers:\n{out}");
    assert!(out.contains("requeue"), "requeue accounting surfaces:\n{out}");
}

#[test]
fn chaos_writes_json_report() {
    let dir = std::env::temp_dir().join("llep_chaos_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("chaos.json");
    run_ok(&[
        "chaos", "--steps", "8", "--faults", "slow:dev=0,x=4;link:x=2", "--out",
        path.to_str().unwrap(),
    ]);
    let text = std::fs::read_to_string(&path).unwrap();
    for key in ["\"faults\"", "\"planners\"", "\"chaos\"", "\"fault_steps\""] {
        assert!(text.contains(key), "chaos JSON missing {key}:\n{text}");
    }
    std::fs::remove_file(path).ok();
}

#[test]
fn serve_accepts_fault_plan() {
    let out = run_ok(&[
        "serve", "--steps", "10", "--faults", "slow:dev=1,x=2", "--planner", "llep",
    ]);
    assert!(out.contains("faults: slow:dev=1,x=2"), "{out}");
    assert!(out.contains("chaos"), "{out}");

    // A failure plan with the default EP/LLEP pair: the EP row renders as
    // unrecoverable while the LLEP row still serves (the table survives).
    let out = run_ok(&["serve", "--steps", "10", "--faults", "fail:dev=0,at=1"]);
    assert!(out.contains("unrecoverable"), "{out}");
    assert!(out.contains("LLEP"), "{out}");
}

#[test]
fn run_on_mixed_pool_shows_heterogeneity_and_bad_faults_fail() {
    let out = run_ok(&["run", "--system", "mixed-h100-a100", "--tokens", "4096"]);
    assert!(out.contains("pool:"), "degraded pool surfaces in the title:\n{out}");
    assert!(out.contains("min speed 0.33"), "{out}");

    let out = llep().args(["run", "--faults", "meteor:dev=1"]).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown fault kind"));

    let out = llep().args(["chaos", "--faults", "fail:dev=99,at=0"]).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("addresses device"));
}

#[test]
fn planner_reads_recommendation_from_tune_report() {
    // tune --out writes a report; --planner @report.json consumes it.
    let dir = std::env::temp_dir().join("llep_pin_consume_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("tune.json");
    run_ok(&[
        "tune", "--budget", "smoke", "--profile", "cpusim4", "--scenario", "concentrated",
        "--tokens", "1024", "--out", path.to_str().unwrap(),
    ]);
    let spec_arg = format!("@{}", path.to_str().unwrap());
    let out = run_ok(&["run", "--planner", &spec_arg, "--tokens", "2048"]);
    assert!(out.contains("planner from"), "{out}");

    // A report without a recommendation field fails loudly.
    let bogus = dir.join("bogus.json");
    std::fs::write(&bogus, "{\"trials\": []}").unwrap();
    let arg = format!("@{}", bogus.to_str().unwrap());
    let out = llep().args(["run", "--planner", &arg]).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("recommended.spec"));
    std::fs::remove_file(path).ok();
    std::fs::remove_file(bogus).ok();
}

#[test]
fn tune_pin_bootstraps_verifies_and_detects_drift() {
    let dir = std::env::temp_dir().join("llep_pin_test");
    std::fs::create_dir_all(&dir).unwrap();
    let pin = dir.join("cpusim4.pin");
    std::fs::remove_file(&pin).ok();
    let pin_s = pin.to_str().unwrap().to_string();
    let args: Vec<&str> = vec![
        "tune", "--budget", "smoke", "--profile", "cpusim4", "--scenario", "concentrated",
        "--tokens", "1024", "--pin", &pin_s,
    ];
    let out = run_ok(&args);
    assert!(out.contains("pin bootstrapped"), "{out}");
    assert!(pin.exists());
    let out = run_ok(&args);
    assert!(out.contains("pin ok"), "stable optimum verifies:\n{out}");
    // A poisoned pin simulates a silently-moved optimum: loud failure.
    std::fs::write(&pin, "bogus-spec\n").unwrap();
    let out = llep().args(&args).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("pin mismatch"));
    std::fs::remove_file(&pin).ok();
}

#[test]
fn bench_suite_bootstraps_checks_and_detects_regression() {
    let dir = std::env::temp_dir().join("llep_bench_pin_test");
    std::fs::create_dir_all(&dir).unwrap();
    let pin = dir.join("BENCH_planner.json");
    std::fs::remove_file(&pin).ok();
    let pin_s = pin.to_str().unwrap().to_string();
    let args: Vec<&str> = vec!["bench", "--suite", "hotpath", "--quick", "--check", &pin_s];
    let out = run_ok(&args);
    assert!(out.contains("bench pin bootstrapped"), "{out}");
    assert!(pin.exists());
    // Against its own (just-written) medians with a generous band the
    // suite must pass; against an absurdly fast pin it must fail loudly.
    let relaxed: Vec<&str> = vec![
        "bench", "--suite", "hotpath", "--quick", "--check", &pin_s, "--tolerance", "20.0",
    ];
    let out = run_ok(&relaxed);
    assert!(out.contains("bench pin ok"), "{out}");
    let mut pinned = llep::util::benchkit::BenchSuite::load(&pin).unwrap();
    for r in &mut pinned.results {
        r.median_ns /= 1e6; // an absurdly fast pin: every case regresses
    }
    pinned.save(&pin).unwrap();
    let out = llep().args(&args).output().unwrap();
    assert!(!out.status.success(), "poisoned pin must regress every case");
    assert!(String::from_utf8_lossy(&out.stderr).contains("bench regression"));
    // Unknown suites are loud errors.
    let out = llep().args(["bench", "--suite", "bogus"]).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown bench suite"));
    std::fs::remove_file(&pin).ok();
}

#[test]
fn fleet_overload_cli_sheds_reports_and_guards_admission() {
    let dir = std::env::temp_dir().join("llep_fleet_overload_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("fleet.json");
    let path_s = path.to_str().unwrap();
    let wl = "bursty:n=24,ia=0.0002,burst=12,every=12,prompt=256-1024,decode=2-4";

    // Tiny caps under a 12-wide burst: the protected run must shed,
    // print the overload summary line, and mark the JSON as protected
    // while keeping the token ledger exact.
    let out = run_ok(&[
        "fleet", "--replicas", "2", "--workload", wl, "--queue-cap", "1", "--frontend-cap", "1",
        "--retries", "1", "--out", path_s,
    ]);
    assert!(out.contains("overload: shed"), "{out}");
    assert!(!out.contains("24/24"), "tiny caps must shed part of the burst:\n{out}");
    let text = std::fs::read_to_string(&path).unwrap();
    assert!(text.contains("\"protected\":true"), "{text}");
    assert!(text.contains("\"ledger_exact\":true"), "{text}");
    assert!(text.contains("\"overload\""), "{text}");
    std::fs::remove_file(path).ok();

    // The same workload without protection keeps the strict contract:
    // every request completes and no overload line is printed.
    let out = run_ok(&["fleet", "--replicas", "2", "--workload", wl]);
    assert!(out.contains("24/24"), "{out}");
    assert!(!out.contains("overload: shed"), "{out}");

    // Admission control estimates against the SLO deadline, so asking
    // for it without one is a loud configuration error.
    let out = llep().args(["fleet", "--replicas", "2", "--admission"]).output().unwrap();
    assert!(!out.status.success());
    assert!(
        String::from_utf8_lossy(&out.stderr).contains("--admission requires --deadline"),
        "{}",
        String::from_utf8_lossy(&out.stderr)
    );
}

#[test]
fn calibrate_fits_model() {
    let out = run_ok(&["calibrate"]);
    assert!(out.contains("peak_flops"));
    assert!(out.contains("overhead_s"));
}

#[test]
fn unknown_flag_and_subcommand_fail_loudly() {
    let out = llep().args(["figures", "--bogus"]).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown option"));

    let out = llep().args(["frobnicate"]).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown subcommand"));
}

#[test]
fn help_prints_usage() {
    let out = run_ok(&["--help"]);
    assert!(out.contains("usage: llep"));
    assert!(out.contains("--fig"));
}
