//! Hot-path equivalence contracts for the zero-allocation planner and
//! canonical-transfer pricing.
//!
//! 1. The arena-backed, heap-spill LLA (`plan_llep`/`plan_llep_pool`)
//!    must be **bit-identical** to the historical allocating
//!    implementation (per-spill re-sort, fresh vectors per plan) across
//!    random `(loads, pool, alpha, m, lambda)` draws — the reference is
//!    reimplemented verbatim below so the equivalence is checked against
//!    the algorithm, not against the code under test.
//! 2. Reusing one `PlanScratch` across many plans changes nothing vs a
//!    fresh arena per plan.
//! 3. `price_plan` is invariant to the order a plan's transfer list is
//!    stored in (canonical construction order vs any shuffle) — the
//!    plan-reuse pricing contract from PR 2 extended to the borrowed
//!    slice fast path.

use llep::config::{LlepConfig, ModelConfig, ModelPreset, SystemConfig, SystemPreset};
use llep::exec::{price_plan, Engine};
use llep::planner::validate::validate_plan;
use llep::planner::{
    plan_llep, plan_llep_pool, plan_llep_scratch, PlanScratch, Planner, PlannerKind, RoutePlan,
    Segment, WeightTransfer,
};
use llep::prelude::PoolState;
use llep::routing::Scenario;
use llep::util::prop::{assert_property, no_shrink};
use llep::util::rng::Rng;

// ---------------------------------------------------------------------------
// Reference implementation: the PR-4 allocating LLA/LLAS (sort-based
// spill, fresh vectors), kept verbatim modulo visibility.
// ---------------------------------------------------------------------------

fn reference_llep(
    cfg: &LlepConfig,
    num_experts: usize,
    devices: usize,
    loads: &[u64],
    speeds: Option<&[f64]>,
) -> RoutePlan {
    assert_eq!(loads.len(), num_experts);
    assert!(devices > 0 && num_experts % devices == 0, "N must divide P");
    let m_per_dev = num_experts / devices;
    let total: u64 = loads.iter().sum();
    let mut plan = RoutePlan {
        num_experts,
        devices,
        assignments: vec![Vec::new(); num_experts],
        transfers: Vec::new(),
        migrations: Vec::new(),
        fallback_ep: false,
    };
    if total == 0 {
        return plan;
    }

    let m_alpha = cfg.alpha * total as f64 / devices as f64;
    let caps: Option<Vec<f64>> = speeds.map(|s| {
        let sum: f64 = s.iter().sum();
        s.iter().map(|&sd| cfg.alpha * total as f64 * sd / sum.max(f64::MIN_POSITIVE)).collect()
    });
    let cap_of = |d: usize| -> f64 {
        match &caps {
            None => m_alpha,
            Some(c) => c[d],
        }
    };
    let min_chunk = cfg.min_gemm_tokens as u64;

    let mut order: Vec<usize> = (0..num_experts).collect();
    order.sort_unstable_by_key(|&e| (std::cmp::Reverse(loads[e]), e));

    let mut g_p: Vec<u64> = vec![0; devices];
    for (e, &l) in loads.iter().enumerate() {
        g_p[e / m_per_dev] += l;
    }
    let mut g_a: Vec<u64> = vec![0; devices];
    let mut seen: Vec<bool> = vec![false; devices];
    let mut others_scratch: Vec<usize> = Vec::with_capacity(devices);

    for &e in &order {
        let load = loads[e];
        let ng = e / m_per_dev;
        g_p[ng] -= load;
        if load == 0 {
            continue;
        }
        let mut segs: Vec<Segment> = Vec::new();

        let native_dead = speeds.is_some_and(|s| s[ng] <= 0.0);
        let occupied = (g_a[ng] + g_p[ng]) as f64;
        let na = if native_dead { i64::MIN } else { (cap_of(ng) - occupied).floor() as i64 };

        if !native_dead && na >= load as i64 {
            segs.push(Segment { device: ng, start: 0, end: load, forced: false });
            g_a[ng] += load;
        } else if na > 0 {
            let nc = (na as u64).min(load);
            let remaining = load - nc;
            if remaining < min_chunk {
                segs.push(Segment { device: ng, start: 0, end: load, forced: true });
                g_a[ng] += load;
            } else {
                segs.push(Segment { device: ng, start: 0, end: nc, forced: false });
                g_a[ng] += nc;
                reference_spill(
                    ng, remaining, nc, &mut segs, &mut g_a, &g_p, &cap_of, min_chunk, None,
                    speeds, &mut others_scratch,
                );
            }
        } else if load < min_chunk && !native_dead {
            segs.push(Segment { device: ng, start: 0, end: load, forced: true });
            g_a[ng] += load;
        } else {
            reference_spill(
                ng, load, 0, &mut segs, &mut g_a, &g_p, &cap_of, min_chunk, None, speeds,
                &mut others_scratch,
            );
        }

        reference_merge(&mut segs);
        for s in &segs {
            if s.device != ng && !seen[s.device] {
                seen[s.device] = true;
                plan.transfers.push(WeightTransfer { expert: e, from: ng, to: s.device });
            }
        }
        for s in &segs {
            seen[s.device] = false;
        }
        plan.assignments[e] = segs;
    }
    plan
}

#[allow(clippy::too_many_arguments)]
fn reference_spill(
    ng: usize,
    mut r: u64,
    mut to: u64,
    segs: &mut Vec<Segment>,
    g_a: &mut [u64],
    g_p: &[u64],
    cap_of: &dyn Fn(usize) -> f64,
    min_chunk: u64,
    _topo: Option<()>,
    speeds: Option<&[f64]>,
    others: &mut Vec<usize>,
) {
    let devices = g_a.len();
    while r > 0 {
        others.clear();
        match speeds {
            None => others.extend((0..devices).filter(|&d| d != ng)),
            Some(s) => others.extend((0..devices).filter(|&d| d != ng && s[d] > 0.0)),
        }
        if others.is_empty() {
            segs.push(Segment { device: ng, start: to, end: to + r, forced: true });
            g_a[ng] += r;
            return;
        }
        match speeds {
            None => others.sort_by_key(|&d| (g_a[d] + g_p[d], 0u8, d)),
            Some(s) => others.sort_by(|&a, &b| {
                let norm = |d: usize| (g_a[d] + g_p[d]) as f64 / s[d];
                norm(a).total_cmp(&norm(b)).then(a.cmp(&b))
            }),
        }

        let mut assigned = false;
        for &o in others.iter() {
            let occupied = (g_a[o] + g_p[o]) as f64;
            let cap = (cap_of(o) - occupied).floor() as i64;
            if cap <= 0 {
                continue;
            }
            let c = r.min(cap as u64);
            if c < min_chunk && r > c {
                continue;
            }
            segs.push(Segment { device: o, start: to, end: to + c, forced: false });
            g_a[o] += c;
            r -= c;
            to += c;
            assigned = true;
            break;
        }

        if !assigned {
            let o = others[0];
            segs.push(Segment { device: o, start: to, end: to + r, forced: true });
            g_a[o] += r;
            return;
        }
    }
}

fn reference_merge(segs: &mut Vec<Segment>) {
    let mut out: Vec<Segment> = Vec::with_capacity(segs.len());
    for s in segs.drain(..) {
        if let Some(last) = out.last_mut() {
            if last.device == s.device && last.end == s.start {
                last.end = s.end;
                last.forced |= s.forced;
                continue;
            }
        }
        out.push(s);
    }
    *segs = out;
}

// ---------------------------------------------------------------------------
// Property inputs
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
struct Draw {
    loads: Vec<u64>,
    devices: usize,
    cfg: LlepConfig,
    /// Effective speeds (0.0 = dead) — None for a homogeneous run.
    speeds: Option<Vec<f64>>,
}

fn gen_draw(rng: &mut Rng) -> Draw {
    let devices = [2usize, 4, 8][rng.index(3)];
    let experts_per = 1 + rng.index(8);
    let n = devices * experts_per;
    let mut loads: Vec<u64> = (0..n).map(|_| rng.below(2_000)).collect();
    // Concentrate a hotspot often enough to exercise the spill loop.
    if rng.index(4) != 0 {
        let hot = rng.index(n);
        loads[hot] += 10_000 + rng.below(50_000);
    }
    let cfg = LlepConfig {
        alpha: [1.0, 1.25, 1.5, 2.0][rng.index(4)],
        min_gemm_tokens: [1usize, 16, 64, 1024][rng.index(4)],
        lambda: [1.0, 1.1, 1.3, 2.0][rng.index(4)],
    };
    let speeds = if rng.index(2) == 0 {
        None
    } else {
        let mut s: Vec<f64> =
            (0..devices).map(|_| [0.25, 0.33, 0.5, 1.0, 1.0, 2.0][rng.index(6)]).collect();
        // Kill at most devices-1 so at least one stays schedulable.
        let deaths = rng.index(devices);
        for _ in 0..deaths {
            let d = rng.index(devices);
            if s.iter().filter(|&&x| x > 0.0).count() > 1 {
                s[d] = 0.0;
            }
        }
        Some(s)
    };
    Draw { loads, devices, cfg, speeds }
}

fn pool_from_speeds(speeds: &[f64]) -> PoolState {
    let mut p = PoolState::healthy(speeds.len());
    for (d, &s) in speeds.iter().enumerate() {
        if s <= 0.0 {
            p.devices[d].alive = false;
        } else {
            p.devices[d].speed = s;
        }
    }
    p
}

// ---------------------------------------------------------------------------
// 1. heap-spill + arena == reference allocating implementation
// ---------------------------------------------------------------------------

#[test]
fn scratch_planning_matches_reference_bit_identically() {
    assert_property(
        "arena/heap LLA == PR-4 allocating LLA",
        0xB07,
        300,
        gen_draw,
        |draw: &Draw| {
            let n = draw.loads.len();
            let (got, want) = match &draw.speeds {
                None => (
                    plan_llep(&draw.cfg, n, draw.devices, &draw.loads, None),
                    reference_llep(&draw.cfg, n, draw.devices, &draw.loads, None),
                ),
                Some(s) => (
                    plan_llep_pool(
                        &draw.cfg,
                        n,
                        draw.devices,
                        &draw.loads,
                        None,
                        &pool_from_speeds(s),
                    ),
                    reference_llep(&draw.cfg, n, draw.devices, &draw.loads, Some(s)),
                ),
            };
            if got.assignments != want.assignments {
                return Err(format!(
                    "assignments diverge:\n got {:?}\nwant {:?}",
                    got.assignments, want.assignments
                ));
            }
            // The new planner stores transfers canonically; the reference
            // emits them in visit order — compare canonicalized.
            let mut want_t = want.transfers.clone();
            want_t.sort_unstable_by_key(|t| (t.to, t.from, t.expert));
            if got.transfers != want_t {
                return Err(format!(
                    "transfers diverge:\n got {:?}\nwant {:?}",
                    got.transfers, want_t
                ));
            }
            if !got.transfers_canonical() {
                return Err("plan not canonical at construction".into());
            }
            validate_plan(&got, &draw.loads).map_err(|e| format!("invalid plan: {e}"))
        },
        no_shrink,
    );
}

// ---------------------------------------------------------------------------
// 2. arena reuse changes nothing
// ---------------------------------------------------------------------------

#[test]
fn reused_arena_is_bit_identical_to_fresh_arena() {
    let mut rng = Rng::new(42);
    let mut reused = PlanScratch::new();
    for _ in 0..120 {
        let draw = gen_draw(&mut rng);
        let n = draw.loads.len();
        let pool = draw.speeds.as_deref().map(pool_from_speeds);
        let fresh = plan_llep_scratch(
            &draw.cfg,
            n,
            draw.devices,
            &draw.loads,
            None,
            pool.as_ref(),
            &mut PlanScratch::new(),
        );
        let warm = plan_llep_scratch(
            &draw.cfg,
            n,
            draw.devices,
            &draw.loads,
            None,
            pool.as_ref(),
            &mut reused,
        );
        assert_eq!(fresh, warm, "arena reuse must not change the plan: {draw:?}");
        reused.recycle(warm);
    }
}

// ---------------------------------------------------------------------------
// 3. pricing is invariant to transfer storage order
// ---------------------------------------------------------------------------

#[test]
fn price_plan_bit_identical_for_any_transfer_order() {
    let engine = Engine::modeled(
        ModelConfig::preset(ModelPreset::Fig1Layer),
        SystemConfig::preset(SystemPreset::H200x8),
    );
    let kind = PlannerKind::llep_default();
    let mut rng = Rng::new(9);
    for case in 0..20 {
        let lm = Scenario::concentrated(0.9, 1 + case % 4).generate_loads(
            &engine.model,
            8,
            16_384,
            &mut rng,
        );
        let plan = kind.plan(8, &lm.expert_loads(), Some(&engine.topo));
        assert!(plan.transfers_canonical());
        let canonical = price_plan(&engine, &plan, &lm, &kind, 0.0, None);

        // Scramble the transfer list (reverse + rotate): the cold
        // fallback path must sort back to the identical accumulation
        // order, so every float agrees to the bit.
        let mut scrambled = plan.clone();
        scrambled.transfers.reverse();
        if scrambled.transfers.len() > 2 {
            scrambled.transfers.rotate_left(1);
        }
        if scrambled.transfers.len() > 1 {
            assert!(!scrambled.transfers_canonical(), "scramble must break canonical order");
        }
        let shuffled = price_plan(&engine, &scrambled, &lm, &kind, 0.0, None);

        assert_eq!(canonical.latency_s.to_bits(), shuffled.latency_s.to_bits());
        assert_eq!(
            canonical.phases.weights_s.to_bits(),
            shuffled.phases.weights_s.to_bits()
        );
        assert_eq!(canonical.device_compute_s, shuffled.device_compute_s);
        assert_eq!(canonical.device_peak_bytes, shuffled.device_peak_bytes);
        assert_eq!(canonical.bytes_weights, shuffled.bytes_weights);
    }
}

// ---------------------------------------------------------------------------
// 4. every in-tree planner constructs canonical plans
// ---------------------------------------------------------------------------

#[test]
fn all_builtin_planners_emit_canonical_transfers() {
    let mut rng = Rng::new(5);
    for spec in ["ep", "llep:m=16", "eplb:r=6", "lpt:min=64", "cached(llep:m=16)"] {
        let planner = llep::planner::parse_planner(spec).unwrap();
        for _ in 0..10 {
            let draw = gen_draw(&mut rng);
            let n = draw.loads.len();
            let plan = planner.plan_with_stats(draw.devices, &draw.loads, &draw.loads, None);
            assert_eq!(plan.num_experts, n);
            assert!(plan.transfers_canonical(), "{spec}: {:?}", plan.transfers);
        }
    }
}
