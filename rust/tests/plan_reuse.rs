//! Integration tests for the trait-based planner architecture and the
//! cross-step plan cache: `--planner` spec round-trips through the
//! registry for all five planners, a cache hit on an *identical* load
//! matrix prices bit-identically to a fresh plan, and a drifted-load hit
//! is honest — the reused plan never balances (and on structural drift
//! never prices) better than replanning would.

use llep::config::LlepConfig;
use llep::exec::price_plan;
use llep::planner::validate::validate_plan;
use llep::planner::{retarget_plan, CachedPlanner, Llep, Planner, Registry};
use llep::prelude::*;
use llep::routing::LoadMatrix;
use llep::util::prop::{assert_property, no_shrink};

fn engine() -> Engine {
    Engine::modeled(
        ModelConfig::preset(ModelPreset::Fig1Layer), // N=128 experts
        SystemConfig::preset(SystemPreset::H200x8),
    )
}

/// Load matrix with every token originating on device 0 (K=1): the
/// planner and cost models only consume per-expert totals and origin
/// rows, so this is the minimal harness for pricing a raw load vector.
fn lm_from_loads(loads: &[u64], devices: usize) -> LoadMatrix {
    let mut counts = vec![vec![0u64; loads.len()]; devices];
    counts[0] = loads.to_vec();
    LoadMatrix { counts, top_k: 1 }
}

#[test]
fn registry_round_trips_all_five_planners() {
    // Acceptance: EP, LLEP, EPLB, ChunkedEP, LPT all round-trip through
    // the registry parser (spec -> planner -> canonical spec -> planner).
    let specs =
        ["ep", "llep:alpha=1.25,m=256,lambda=1.1", "eplb:r=4", "chunked:c=1024", "lpt:min=2048"];
    let mut labels = Vec::new();
    for spec in specs {
        let p = Registry::builtin().parse(spec).unwrap_or_else(|e| panic!("{spec}: {e}"));
        let canon = p.spec();
        let p2 = Registry::builtin()
            .parse(&canon)
            .unwrap_or_else(|e| panic!("canonical {canon}: {e}"));
        assert_eq!(p2.spec(), canon, "{spec} must be a spec fixed point");
        assert_eq!(p2.label(), p.label(), "{spec} must reconstruct the same planner");
        labels.push(p.label());
    }
    for prefix in ["EP", "LLEP", "EPLB", "ChunkedEP", "LPT"] {
        assert!(
            labels.iter().any(|l| l.starts_with(prefix)),
            "planner {prefix} missing from {labels:?}"
        );
    }
    // ... and every parsed planner actually plans through the trait.
    let loads = vec![5_000u64; 128];
    for spec in specs {
        let p = Registry::builtin().parse(spec).unwrap();
        let plan = p.plan(8, &loads, None);
        validate_plan(&plan, &loads).unwrap_or_else(|e| panic!("{spec}: {e}"));
    }
}

#[test]
fn cached_hit_prices_identically_to_fresh_on_unchanged_loads() {
    let e = engine();
    let mut rng = Rng::new(42);
    let lm = Scenario::concentrated(0.9, 1).generate_loads(&e.model, 8, 8192, &mut rng);

    let fresh = e.run_step_loads(&lm, &PlannerKind::llep_default());
    let cached = CachedPlanner::new(PlannerKind::llep_default().boxed());
    let miss = e.run_step_loads(&lm, &cached);
    let hit = e.run_step_loads(&lm, &cached);
    assert_eq!(miss.cache.misses, 1);
    assert_eq!(hit.cache.hits, 1);

    // Every deterministic pricing quantity is bit-identical across all
    // three; only the measured plan wall time may differ.
    for r in [&miss, &hit] {
        assert_eq!(r.device_compute_s, fresh.device_compute_s);
        assert_eq!(r.device_peak_bytes, fresh.device_peak_bytes);
        assert_eq!(r.bytes_dispatch, fresh.bytes_dispatch);
        assert_eq!(r.bytes_combine, fresh.bytes_combine);
        assert_eq!(r.bytes_weights, fresh.bytes_weights);
        assert_eq!(r.gemm_calls, fresh.gemm_calls);
        assert_eq!(r.weight_transfers, fresh.weight_transfers);
        assert_eq!(r.tokens, fresh.tokens);
        assert_eq!(r.phases.dispatch_s, fresh.phases.dispatch_s);
        assert_eq!(r.phases.weights_s, fresh.phases.weights_s);
        assert_eq!(r.phases.compute_s, fresh.phases.compute_s);
        assert_eq!(r.phases.combine_s, fresh.phases.combine_s);
    }
}

/// Random load vectors: mixture of zeros, small and large entries, with
/// a hot head so the lambda guard usually engages.
fn gen_loads(rng: &mut Rng) -> Vec<u64> {
    (0..128)
        .map(|i| {
            if i < 4 {
                20_000 + rng.below(200_000)
            } else {
                match rng.index(3) {
                    0 => 0,
                    1 => rng.below(500),
                    _ => rng.below(20_000),
                }
            }
        })
        .collect()
}

#[test]
fn prop_identity_retarget_prices_bit_identically() {
    let e = engine();
    let kind = PlannerKind::Llep(LlepConfig {
        alpha: 1.0,
        min_gemm_tokens: 64,
        lambda: 1.0,
    });
    assert_property(
        "identity retarget prices bit-identically",
        0xCAFE,
        120,
        gen_loads,
        |loads| {
            let lm = lm_from_loads(loads, 8);
            let fresh = kind.plan(8, loads, Some(&e.topo));
            let reused = retarget_plan(&fresh, loads, loads);
            validate_plan(&reused, loads)?;
            let pf = price_plan(&e, &fresh, &lm, &kind, 0.0, None);
            let pr = price_plan(&e, &reused, &lm, &kind, 0.0, None);
            if pf.latency_s != pr.latency_s {
                return Err(format!("latency {} != {}", pf.latency_s, pr.latency_s));
            }
            if pf.device_compute_s != pr.device_compute_s {
                return Err("device compute differs".into());
            }
            if pf.device_peak_bytes != pr.device_peak_bytes {
                return Err("peak memory differs".into());
            }
            Ok(())
        },
        no_shrink,
    );
}

#[test]
fn prop_drifted_reuse_never_balances_better_than_replanning() {
    // The honesty property at the token level: when the fresh plan is
    // capacity-clean (no forced segments, no lambda fallback), its max
    // device load is <= floor(m_alpha) by the LLA capacity contract,
    // while *any* plan — in particular a stale retargeted one — carries
    // at least ceil(total/P) = ceil(m_alpha) somewhere. A reused stale
    // plan can therefore never balance better than replanning; at best
    // it ties.
    let kind = PlannerKind::Llep(LlepConfig {
        alpha: 1.0,
        min_gemm_tokens: 8,
        lambda: 1.0,
    });
    assert_property(
        "drifted reuse never balances better",
        0xBEEF,
        120,
        |rng| {
            let old = gen_loads(rng);
            // Drift: jitter every expert by up to ~25% and move some mass
            // onto a new hot expert.
            let mut new = old.clone();
            for l in new.iter_mut() {
                let span = (*l / 4).max(1);
                let down = rng.below(span + 1);
                let up = rng.below(span + 1);
                *l = l.saturating_sub(down) + up;
            }
            let hot = 4 + rng.index(124);
            new[hot] += 50_000;
            (old, new)
        },
        |(old, new)| {
            let fresh_old = kind.plan(8, old, None);
            let stale = retarget_plan(&fresh_old, old, new);
            validate_plan(&stale, new).map_err(|e| format!("stale plan invalid: {e}"))?;
            let fresh_new = kind.plan(8, new, None);
            let clean = !fresh_new.fallback_ep
                && fresh_new.assignments.iter().flatten().all(|s| !s.forced);
            if clean {
                let stale_max = *stale.device_loads().iter().max().unwrap();
                let fresh_max = *fresh_new.device_loads().iter().max().unwrap();
                if stale_max < fresh_max {
                    return Err(format!(
                        "stale plan balances better: {stale_max} < {fresh_max}"
                    ));
                }
            }
            Ok(())
        },
        no_shrink,
    );
}

#[test]
fn prop_repair_tier_restores_capacity_on_drift() {
    // The O(Δ) repair contract, point-checked across random drifted
    // draws: moving a few percent of total load off the hot expert puts
    // the drift inside the repair band, so the second lookup must take
    // the Repaired path; the repaired plan validates against the new
    // loads; and — whenever the repair needed no forced placements and
    // no EP fallback — it restores the LLA capacity bound and is never
    // worse-balanced than the stale retarget it started from.
    let cfg = LlepConfig { alpha: 1.0, min_gemm_tokens: 64, lambda: 1.0 };
    assert_property(
        "repair tier restores capacity on drift",
        0xD017,
        120,
        |rng| {
            let old = gen_loads(rng);
            let total: u64 = old.iter().sum();
            let hot = (0..old.len()).max_by_key(|&e| old[e]).unwrap();
            // 3–5% of total mass: drift ≈ 0.06–0.09, inside (0.05, 0.2].
            let moved = (total / 32 + rng.below(total / 64 + 1)).min(old[hot]);
            let dst = 4 + rng.index(124);
            let mut new = old.clone();
            new[hot] -= moved;
            new[dst] += moved;
            (old, new)
        },
        |(old, new)| {
            let cached = CachedPlanner::new(Box::new(Llep::new(cfg))).with_repair_ceiling(0.2);
            let first = cached.plan(8, old, None);
            let stale = retarget_plan(&first, old, new);
            let repaired = cached.plan(8, new, None);
            match cached.last_cache_outcome() {
                Some(llep::planner::CacheOutcome::Repaired) => {}
                // A hot expert too light to absorb the move can leave the
                // drift under the retarget threshold — nothing to repair.
                Some(llep::planner::CacheOutcome::Hit) => return Ok(()),
                o => return Err(format!("expected a repair, got {o:?}")),
            }
            validate_plan(&repaired, new).map_err(|e| format!("repaired plan invalid: {e}"))?;
            let forced = repaired.assignments.iter().flatten().any(|s| s.forced);
            if repaired.fallback_ep || forced {
                return Ok(());
            }
            let total: u64 = new.iter().sum();
            let cap =
                (cfg.alpha * total as f64 / 8.0).floor() as u64 + cfg.min_gemm_tokens as u64;
            let rmax = *repaired.device_loads().iter().max().unwrap();
            if rmax > cap {
                return Err(format!("repaired max {rmax} exceeds capacity {cap}"));
            }
            let smax = *stale.device_loads().iter().max().unwrap();
            if rmax > smax {
                return Err(format!("repair made balance worse: {rmax} > {smax}"));
            }
            Ok(())
        },
        no_shrink,
    );
}

#[test]
fn moved_hotspot_prices_stale_reuse_strictly_worse() {
    // Structural drift: the hot expert moves across the machine. The
    // stale plan keeps splitting the *old* hot expert and leaves the new
    // one whole on its native device — pricing (with equal plan time)
    // must show the reused plan as clearly worse than replanning, i.e.
    // reuse is never silently flattering.
    let e = engine();
    let kind = PlannerKind::llep_default();
    let mut rng = Rng::new(7);
    let lm_a = Scenario::concentrated(0.9, 1).generate_loads(&e.model, 8, 16_384, &mut rng);
    let loads_a = lm_a.expert_loads();
    // Rotate the distribution by half the machine: expert 64 is now hot.
    let n = loads_a.len();
    let loads_b: Vec<u64> = (0..n).map(|i| loads_a[(i + 64) % n]).collect();
    let lm_b = lm_from_loads(&loads_b, 8);

    let plan_a = kind.plan(8, &loads_a, Some(&e.topo));
    let stale = retarget_plan(&plan_a, &loads_a, &loads_b);
    validate_plan(&stale, &loads_b).unwrap();
    let fresh = kind.plan(8, &loads_b, Some(&e.topo));

    let stale_priced = price_plan(&e, &stale, &lm_b, &kind, 0.0, None);
    let fresh_priced = price_plan(&e, &fresh, &lm_b, &kind, 0.0, None);
    assert!(
        stale_priced.latency_s > fresh_priced.latency_s * 1.5,
        "stale {} vs fresh {}: structural drift must price the reused plan much worse",
        stale_priced.latency_s,
        fresh_priced.latency_s
    );
}

#[test]
fn band_changed_pool_takes_the_repair_tier_end_to_end() {
    // Pool-fingerprint regression, end-to-end through the engine: the
    // same straggler seen through measurement noise (one quantization
    // step on the per-device fingerprint) band-matches the cached entry.
    // The step must take the O(Δ) repair tier — pricing T_plan as
    // hit_s + peeled × repair_s, strictly below a fresh plan — instead
    // of cold-missing, and the repair re-anchors the entry so replaying
    // the wobbled pool is a plain hit.
    let cost = PlanCostModel::default();
    let base = engine().with_plan_cost(cost);
    let mut rng = Rng::new(17);
    let loads = gen_loads(&mut rng);
    let lm = lm_from_loads(&loads, 8);

    let mut pool = PoolState::healthy(8);
    pool.devices[0].speed = 0.25; // fingerprint round(256·s) = 64
    let mut wobble = pool.clone();
    wobble.devices[0].speed = 0.246; // fingerprint 63: one band step slower

    let cached = CachedPlanner::new(Box::new(Llep::new(LlepConfig::default())))
        .with_repair_ceiling(0.2);
    let miss = base.for_pool(pool).run_step_loads(&lm, &cached);
    assert_eq!(miss.cache.misses, 1);
    assert_eq!(miss.phases.plan_s.to_bits(), cost.fresh_s.to_bits());

    let wobbled = base.for_pool(wobble);
    let repaired = wobbled.run_step_loads(&lm, &cached);
    assert_eq!(repaired.cache.repairs, 1, "band-matched pool must repair, not cold-miss");
    assert!(!repaired.stranded && !repaired.oom);
    assert!(
        repaired.phases.plan_s < cost.fresh_s,
        "repair prices below a fresh plan: {}",
        repaired.phases.plan_s
    );
    // The slower device shed capacity, so the repair peeled at least one
    // segment: T_plan = hit_s + k·repair_s for an integral k >= 1.
    let peels = (repaired.phases.plan_s - cost.hit_s) / cost.repair_s;
    assert!(
        peels >= 1.0 - 1e-9 && (peels - peels.round()).abs() < 1e-6,
        "plan time must be an integral number of peels above hit_s, got {peels}"
    );

    let hit = wobbled.run_step_loads(&lm, &cached);
    assert_eq!(hit.cache.hits, 1, "the repair re-anchored the pool fingerprint");
    assert_eq!(hit.phases.plan_s.to_bits(), cost.hit_s.to_bits());
}

#[test]
fn cached_planner_multi_layer_steps_hit_per_layer() {
    // A 4-layer model planned through one shared cache: the second
    // identical model step hits on every layer and prices each layer's
    // deterministic quantities identically to a fresh LLEP step.
    let mut model = ModelConfig::preset(ModelPreset::Fig1Layer);
    model.num_layers = 4;
    let e = Engine::modeled(model.clone(), SystemConfig::preset(SystemPreset::H200x8));
    let profile = DepthProfile::varying(&model, 0.5, 0.0);
    let mut rng = Rng::new(3);
    let lms = profile.generate_loads(&model, 8, 8192, &mut rng);

    let cached = CachedPlanner::new(PlannerKind::llep_default().boxed());
    let first = e.run_model(&lms, &cached).unwrap();
    assert_eq!(first.cache.lookups(), 4, "one lookup per layer");
    let second = e.run_model(&lms, &cached).unwrap();
    assert_eq!(second.cache.hits, 4, "identical step: every layer reuses");

    let fresh = e.run_model(&lms, &PlannerKind::llep_default()).unwrap();
    for (a, b) in second.layers.iter().zip(&fresh.layers) {
        assert_eq!(a.report.device_compute_s, b.report.device_compute_s);
        assert_eq!(a.report.device_peak_bytes, b.report.device_peak_bytes);
        assert_eq!(a.report.bytes_dispatch, b.report.bytes_dispatch);
    }
}

#[test]
fn spec_parsing_composes_with_cached_decorator() {
    let p = Registry::builtin().parse("cached(lpt:min=256):drift=0.2,every=8").unwrap();
    assert_eq!(p.label(), "Cached[LPT(min=256)]");
    assert!(!p.replay_safe());
    let loads = vec![10_000u64, 0, 0, 0, 0, 0, 0, 2_000];
    let a = p.plan(4, &loads, None);
    validate_plan(&a, &loads).unwrap();
    let b = p.plan(4, &loads, None);
    validate_plan(&b, &loads).unwrap();
    assert_eq!(p.last_cache_outcome(), Some(llep::planner::CacheOutcome::Hit));
}

#[test]
fn llep_struct_and_kind_agree_through_the_trait() {
    // The thin-constructor contract: PlannerKind::Llep and the concrete
    // Llep struct are the same planner.
    let loads = vec![50_000u64, 100, 0, 900, 40, 0, 0, 60];
    let cfg = LlepConfig::default();
    let via_struct = Llep::new(cfg).plan(4, &loads, None);
    let via_kind = PlannerKind::Llep(cfg).plan(4, &loads, None);
    assert_eq!(via_struct, via_kind);
}
