//! Fleet-level integration tests: bit-reproducibility of the cluster
//! simulator, router-policy behaviour under heterogeneous replicas, the
//! summed-ledger identity, and whole-replica failure recovery.

use llep::config::{ModelConfig, ModelPreset, SystemConfig, SystemPreset};
use llep::coordinator::TokenLedger;
use llep::exec::Engine;
use llep::fleet::{
    FleetEvent, FleetFaultPlan, FleetReport, FleetSim, ReplicaConfig, RouterPolicy, Workload,
};
use llep::routing::Scenario;
use llep::util::prop::{assert_property, no_shrink};
use llep::util::rng::Rng;

fn engine() -> Engine {
    Engine::modeled(
        ModelConfig::preset(ModelPreset::Fig1Layer),
        SystemConfig::preset(SystemPreset::H200x8),
    )
}

fn fleet(replicas: Vec<ReplicaConfig>, workload: &str) -> FleetSim {
    FleetSim::new(engine(), Scenario::concentrated(0.8, 4), replicas, 16_384)
        .with_workload(Workload::parse(workload).unwrap())
}

fn assert_bit_identical(a: &FleetReport, b: &FleetReport) -> Result<(), String> {
    if a.makespan_s.to_bits() != b.makespan_s.to_bits() {
        return Err(format!("makespan differs: {} vs {}", a.makespan_s, b.makespan_s));
    }
    if a.ttft.mean.to_bits() != b.ttft.mean.to_bits()
        || a.tpot.mean.to_bits() != b.tpot.mean.to_bits()
        || a.request_latency.p99.to_bits() != b.request_latency.p99.to_bits()
    {
        return Err("latency summaries differ".into());
    }
    if a.tokens != b.tokens {
        return Err(format!("ledgers differ: {:?} vs {:?}", a.tokens, b.tokens));
    }
    for (i, (x, y)) in a.replicas.iter().zip(&b.replicas).enumerate() {
        if x.steps != y.steps || x.routed != y.routed || x.tokens != y.tokens {
            return Err(format!("replica {i} diverged"));
        }
    }
    Ok(())
}

/// The fleet run is a pure function of (workload spec, replica configs,
/// fault plan, seed): re-running produces bit-identical reports across
/// seeds and router policies.
#[test]
fn fleet_run_is_bit_reproducible() {
    const ROUTERS: [RouterPolicy; 3] =
        [RouterPolicy::RoundRobin, RouterPolicy::LeastQueue, RouterPolicy::Pressure];
    assert_property(
        "fleet bit-reproducible",
        0xF1EE7,
        4,
        |rng| (rng.index(10_000) as u64, rng.index(ROUTERS.len())),
        |&(seed, router)| {
            let sim = || {
                fleet(
                    vec![ReplicaConfig::default(); 2],
                    "bursty:n=16,ia=0.0004,burst=4,every=8,prompt=128-512,decode=2-8",
                )
                .with_router(ROUTERS[router])
            };
            let a = sim().try_run(seed)?;
            let b = sim().try_run(seed)?;
            if a.completed != a.requests {
                return Err(format!("lost requests: {}/{}", a.completed, a.requests));
            }
            assert_bit_identical(&a, &b)
        },
        no_shrink,
    );
}

/// Satellite contract: under a bursty workload with one slow replica,
/// queue-aware routing beats load-blind round-robin on p99 TTFT (the
/// round-robin router keeps feeding the replica whose queue never
/// drains).
#[test]
fn least_queue_beats_round_robin_on_p99_ttft_with_slow_replica() {
    let replicas =
        || vec![ReplicaConfig::default(), ReplicaConfig::default().with_speed(0.2)];
    let wl = "bursty:n=32,ia=0.00005,burst=8,every=16,prompt=512-2048,decode=2-8";
    let rr = fleet(replicas(), wl).with_router(RouterPolicy::RoundRobin).try_run(7).unwrap();
    let lq = fleet(replicas(), wl).with_router(RouterPolicy::LeastQueue).try_run(7).unwrap();
    assert_eq!(rr.completed, 32);
    assert_eq!(lq.completed, 32);
    assert!(
        lq.ttft.p99 < rr.ttft.p99,
        "least-queue p99 TTFT {} must beat round-robin {}",
        lq.ttft.p99,
        rr.ttft.p99
    );
    // The slow replica absorbs fewer requests under queue-aware routing.
    assert!(
        lq.replicas[1].routed < rr.replicas[1].routed,
        "lq sent {} to the slow replica, rr sent {}",
        lq.replicas[1].routed,
        rr.replicas[1].routed
    );
}

/// Satellite contract: the fleet ledger is exactly the sum of the
/// per-replica ledgers, and every one of them is individually exact —
/// including across a whole-replica failure's requeues.
#[test]
fn per_replica_ledgers_sum_to_fleet_ledger() {
    let wl = Workload::parse("bursty:n=24,ia=0.0001,burst=12,every=12,prompt=256-1024,decode=2-6")
        .unwrap();
    let arrivals = wl.generate(&mut Rng::new(5));
    // Kill replica 1 just after the first burst has fully arrived, so it
    // is guaranteed to be holding routed work.
    let kill_at = arrivals[11].arrival_s + 1e-6;
    let sim = FleetSim::new(
        engine(),
        Scenario::concentrated(0.8, 4),
        vec![ReplicaConfig::default(); 2],
        16_384,
    )
    .with_workload(wl)
    .with_faults(FleetFaultPlan { events: vec![FleetEvent::Fail { replica: 1, at_s: kill_at }] });
    let r = sim.try_run(5).unwrap();

    let mut sum = TokenLedger::default();
    for p in &r.replicas {
        assert!(p.tokens.is_exact(), "per-replica ledger: {:?}", p.tokens);
        sum.absorb(&p.tokens);
    }
    assert_eq!(sum, r.tokens, "fleet ledger must be the sum of its replicas");
    assert!(r.tokens.is_exact(), "{:?}", r.tokens);
}

/// Whole-replica failure as a chaos domain: every request still
/// completes, each in-flight request requeues at most once, the summed
/// ledger stays exact, and goodput survives.
#[test]
fn whole_replica_failure_recovers_with_bounded_requeues() {
    let wl = Workload::parse("bursty:n=24,ia=0.0001,burst=12,every=12,prompt=256-1024,decode=2-6")
        .unwrap();
    let arrivals = wl.generate(&mut Rng::new(5));
    let kill_at = arrivals[11].arrival_s + 1e-6;
    let sim = FleetSim::new(
        engine(),
        Scenario::concentrated(0.8, 4),
        vec![ReplicaConfig::default(); 2],
        16_384,
    )
    .with_workload(wl)
    .with_faults(FleetFaultPlan { events: vec![FleetEvent::Fail { replica: 1, at_s: kill_at }] });
    let r = sim.try_run(5).unwrap();

    assert_eq!(r.completed, r.requests, "no request may be lost to the failure");
    assert_eq!(r.replica_failures, 1);
    assert!(r.requeued_requests >= 1, "the dead replica was holding routed work");
    assert!(r.max_requeues <= 1, "single failure: at most one requeue per request");
    assert!(r.tokens.is_exact(), "{:?}", r.tokens);
    assert!(r.goodput_tps > 0.0);
    assert_eq!(r.replicas[0].completed, r.requests, "the survivor finished everything");
}

/// Replicas can run different planner policies side by side; the fleet
/// still completes and accounts exactly.
#[test]
fn mixed_planner_fleet_completes() {
    let replicas = vec![
        ReplicaConfig::default().with_planner("llep"),
        ReplicaConfig::default().with_planner("ep"),
    ];
    let r = fleet(replicas, "poisson:n=16,ia=0.0005,prompt=128-512,decode=2-6")
        .with_router(RouterPolicy::Pressure)
        .try_run(3)
        .unwrap();
    assert_eq!(r.completed, 16);
    assert!(r.tokens.is_exact(), "{:?}", r.tokens);
    assert!(r.replicas[0].planner.to_lowercase().contains("ll"), "{}", r.replicas[0].planner);
    assert!(r.replicas[1].planner.to_lowercase().contains("ep"), "{}", r.replicas[1].planner);
}

/// The spec grammars used by `llep fleet` round-trip: workload, router
/// and whole-replica fault plan all reconstruct from their canonical
/// strings.
#[test]
fn fleet_cli_grammars_round_trip() {
    for spec in [
        "poisson:n=64,ia=0.0002,prompt=128-1024,decode=4-32",
        "diurnal:amp=0.5,period=0.05,n=64,ia=0.0002,prompt=128-1024,decode=4-32",
        "bursty:burst=8,every=16,n=64,ia=0.0002,prompt=128-1024,decode=4-32",
    ] {
        let w = Workload::parse(spec).unwrap();
        assert_eq!(Workload::parse(&w.spec()).unwrap(), w, "{spec}");
    }
    for policy in [RouterPolicy::RoundRobin, RouterPolicy::LeastQueue, RouterPolicy::Pressure] {
        assert_eq!(RouterPolicy::parse(policy.name()).unwrap(), policy);
    }
    let plan = FleetFaultPlan::parse("fail:r=1,at=0.001;recover:r=1,at=0.004").unwrap();
    assert_eq!(FleetFaultPlan::parse(&plan.spec()).unwrap(), plan);
}
