//! Fleet-level integration tests: bit-reproducibility of the cluster
//! simulator, router-policy behaviour under heterogeneous replicas, the
//! summed-ledger identity, whole-replica failure recovery, and the
//! overload-protection acceptance contract (admission control +
//! backpressure + retry/backoff beating the unprotected fleet under a
//! correlated replica burst).

use llep::config::{ModelConfig, ModelPreset, SystemConfig, SystemPreset};
use llep::coordinator::TokenLedger;
use llep::exec::Engine;
use llep::fleet::{
    FleetEvent, FleetFaultPlan, FleetReport, FleetSim, OverloadConfig, ReplicaConfig,
    RouterPolicy, Workload,
};
use llep::routing::Scenario;
use llep::util::prop::{assert_property, no_shrink};
use llep::util::rng::Rng;

fn engine() -> Engine {
    Engine::modeled(
        ModelConfig::preset(ModelPreset::Fig1Layer),
        SystemConfig::preset(SystemPreset::H200x8),
    )
}

fn fleet(replicas: Vec<ReplicaConfig>, workload: &str) -> FleetSim {
    FleetSim::new(engine(), Scenario::concentrated(0.8, 4), replicas, 16_384)
        .with_workload(Workload::parse(workload).unwrap())
}

fn assert_bit_identical(a: &FleetReport, b: &FleetReport) -> Result<(), String> {
    if a.makespan_s.to_bits() != b.makespan_s.to_bits() {
        return Err(format!("makespan differs: {} vs {}", a.makespan_s, b.makespan_s));
    }
    if a.ttft.mean.to_bits() != b.ttft.mean.to_bits()
        || a.tpot.mean.to_bits() != b.tpot.mean.to_bits()
        || a.request_latency.p99.to_bits() != b.request_latency.p99.to_bits()
    {
        return Err("latency summaries differ".into());
    }
    if a.tokens != b.tokens {
        return Err(format!("ledgers differ: {:?} vs {:?}", a.tokens, b.tokens));
    }
    for (i, (x, y)) in a.replicas.iter().zip(&b.replicas).enumerate() {
        if x.steps != y.steps || x.routed != y.routed || x.tokens != y.tokens {
            return Err(format!("replica {i} diverged"));
        }
    }
    Ok(())
}

/// The fleet run is a pure function of (workload spec, replica configs,
/// fault plan, seed): re-running produces bit-identical reports across
/// seeds and router policies.
#[test]
fn fleet_run_is_bit_reproducible() {
    const ROUTERS: [RouterPolicy; 3] =
        [RouterPolicy::RoundRobin, RouterPolicy::LeastQueue, RouterPolicy::Pressure];
    assert_property(
        "fleet bit-reproducible",
        0xF1EE7,
        4,
        |rng| (rng.index(10_000) as u64, rng.index(ROUTERS.len())),
        |&(seed, router)| {
            let sim = || {
                fleet(
                    vec![ReplicaConfig::default(); 2],
                    "bursty:n=16,ia=0.0004,burst=4,every=8,prompt=128-512,decode=2-8",
                )
                .with_router(ROUTERS[router])
            };
            let a = sim().try_run(seed)?;
            let b = sim().try_run(seed)?;
            if a.completed != a.requests {
                return Err(format!("lost requests: {}/{}", a.completed, a.requests));
            }
            assert_bit_identical(&a, &b)
        },
        no_shrink,
    );
}

/// Satellite contract: under a bursty workload with one slow replica,
/// queue-aware routing beats load-blind round-robin on p99 TTFT (the
/// round-robin router keeps feeding the replica whose queue never
/// drains).
#[test]
fn least_queue_beats_round_robin_on_p99_ttft_with_slow_replica() {
    let replicas =
        || vec![ReplicaConfig::default(), ReplicaConfig::default().with_speed(0.2)];
    let wl = "bursty:n=32,ia=0.00005,burst=8,every=16,prompt=512-2048,decode=2-8";
    let rr = fleet(replicas(), wl).with_router(RouterPolicy::RoundRobin).try_run(7).unwrap();
    let lq = fleet(replicas(), wl).with_router(RouterPolicy::LeastQueue).try_run(7).unwrap();
    assert_eq!(rr.completed, 32);
    assert_eq!(lq.completed, 32);
    assert!(
        lq.ttft.p99 < rr.ttft.p99,
        "least-queue p99 TTFT {} must beat round-robin {}",
        lq.ttft.p99,
        rr.ttft.p99
    );
    // The slow replica absorbs fewer requests under queue-aware routing.
    assert!(
        lq.replicas[1].routed < rr.replicas[1].routed,
        "lq sent {} to the slow replica, rr sent {}",
        lq.replicas[1].routed,
        rr.replicas[1].routed
    );
}

/// Satellite contract: the fleet ledger is exactly the sum of the
/// per-replica ledgers, and every one of them is individually exact —
/// including across a whole-replica failure's requeues.
#[test]
fn per_replica_ledgers_sum_to_fleet_ledger() {
    let wl = Workload::parse("bursty:n=24,ia=0.0001,burst=12,every=12,prompt=256-1024,decode=2-6")
        .unwrap();
    let arrivals = wl.generate(&mut Rng::new(5));
    // Kill replica 1 just after the first burst has fully arrived, so it
    // is guaranteed to be holding routed work.
    let kill_at = arrivals[11].arrival_s + 1e-6;
    let sim = FleetSim::new(
        engine(),
        Scenario::concentrated(0.8, 4),
        vec![ReplicaConfig::default(); 2],
        16_384,
    )
    .with_workload(wl)
    .with_faults(FleetFaultPlan { events: vec![FleetEvent::Fail { replica: 1, at_s: kill_at }] });
    let r = sim.try_run(5).unwrap();

    let mut sum = TokenLedger::default();
    for p in &r.replicas {
        assert!(p.tokens.is_exact(), "per-replica ledger: {:?}", p.tokens);
        sum.absorb(&p.tokens);
    }
    assert_eq!(sum, r.tokens, "fleet ledger must be the sum of its replicas");
    assert!(r.tokens.is_exact(), "{:?}", r.tokens);
}

/// Whole-replica failure as a chaos domain: every request still
/// completes, each in-flight request requeues at most once, the summed
/// ledger stays exact, and goodput survives.
#[test]
fn whole_replica_failure_recovers_with_bounded_requeues() {
    let wl = Workload::parse("bursty:n=24,ia=0.0001,burst=12,every=12,prompt=256-1024,decode=2-6")
        .unwrap();
    let arrivals = wl.generate(&mut Rng::new(5));
    let kill_at = arrivals[11].arrival_s + 1e-6;
    let sim = FleetSim::new(
        engine(),
        Scenario::concentrated(0.8, 4),
        vec![ReplicaConfig::default(); 2],
        16_384,
    )
    .with_workload(wl)
    .with_faults(FleetFaultPlan { events: vec![FleetEvent::Fail { replica: 1, at_s: kill_at }] });
    let r = sim.try_run(5).unwrap();

    assert_eq!(r.completed, r.requests, "no request may be lost to the failure");
    assert_eq!(r.replica_failures, 1);
    assert!(r.requeued_requests >= 1, "the dead replica was holding routed work");
    assert!(r.max_requeues <= 1, "single failure: at most one requeue per request");
    assert!(r.tokens.is_exact(), "{:?}", r.tokens);
    assert!(r.goodput_tps > 0.0);
    assert_eq!(r.replicas[0].completed, r.requests, "the survivor finished everything");
}

/// Replicas can run different planner policies side by side; the fleet
/// still completes and accounts exactly.
#[test]
fn mixed_planner_fleet_completes() {
    let replicas = vec![
        ReplicaConfig::default().with_planner("llep"),
        ReplicaConfig::default().with_planner("ep"),
    ];
    let r = fleet(replicas, "poisson:n=16,ia=0.0005,prompt=128-512,decode=2-6")
        .with_router(RouterPolicy::Pressure)
        .try_run(3)
        .unwrap();
    assert_eq!(r.completed, 16);
    assert!(r.tokens.is_exact(), "{:?}", r.tokens);
    assert!(r.replicas[0].planner.to_lowercase().contains("ll"), "{}", r.replicas[0].planner);
    assert!(r.replicas[1].planner.to_lowercase().contains("ep"), "{}", r.replicas[1].planner);
}

/// The spec grammars used by `llep fleet` round-trip: workload, router
/// and whole-replica fault plan all reconstruct from their canonical
/// strings.
#[test]
fn fleet_cli_grammars_round_trip() {
    for spec in [
        "poisson:n=64,ia=0.0002,prompt=128-1024,decode=4-32",
        "diurnal:amp=0.5,period=0.05,n=64,ia=0.0002,prompt=128-1024,decode=4-32",
        "bursty:burst=8,every=16,n=64,ia=0.0002,prompt=128-1024,decode=4-32",
    ] {
        let w = Workload::parse(spec).unwrap();
        assert_eq!(Workload::parse(&w.spec()).unwrap(), w, "{spec}");
    }
    for policy in [RouterPolicy::RoundRobin, RouterPolicy::LeastQueue, RouterPolicy::Pressure] {
        assert_eq!(RouterPolicy::parse(policy.name()).unwrap(), policy);
    }
    let plan = FleetFaultPlan::parse("fail:r=1,at=0.001;recover:r=1,at=0.004").unwrap();
    assert_eq!(FleetFaultPlan::parse(&plan.spec()).unwrap(), plan);
    // the correlated-burst macro round-trips through its desugared form
    let burst = FleetFaultPlan::parse("burst:r=1-2,at=0.001,for=0.004").unwrap();
    assert_eq!(burst.events.len(), 4, "2 fails + 2 recovers");
    assert_eq!(FleetFaultPlan::parse(&burst.spec()).unwrap(), burst);
    // and the overload-protection knob block does too
    let cfg = OverloadConfig::parse("queue-cap=4,retries=2,backoff=0.0005").unwrap();
    assert_eq!(OverloadConfig::parse(&cfg.spec()).unwrap(), cfg);
}

/// Tentpole acceptance contract: on a bursty workload with a correlated
/// two-replica outage and a tight SLO deadline, the protected fleet
/// (admission control + queue caps + bounded retries) delivers strictly
/// more goodput and a lower completed-request p99 TTFT than the
/// unprotected fleet, sheds a bounded non-zero fraction with an exact
/// `completed + shed == requests` ledger, and stays bit-reproducible.
#[test]
fn overload_protection_beats_unprotected_under_correlated_burst() {
    let wl_spec = "bursty:n=48,ia=0.0001,burst=12,every=12,prompt=512-2048,decode=2-6";
    let seed = 21;

    // Calibrate the SLO from a healthy 3-replica run of the same
    // workload, so the deadline is tight under overload but trivially
    // meetable when the fleet is whole — no magic latency constants.
    let healthy = fleet(vec![ReplicaConfig::default(); 3], wl_spec).try_run(seed).unwrap();
    assert_eq!(healthy.completed, healthy.requests);
    let deadline = healthy.request_latency.p99 * 1.5;
    assert!(deadline > 0.0);

    // Kill replicas 1 and 2 together just after the second burst has
    // fully arrived (a rack/power-domain failure), for long enough that
    // they never come back while work is pending.
    let arrivals = Workload::parse(wl_spec).unwrap().generate(&mut Rng::new(seed));
    let kill_at = arrivals[23].arrival_s + 1e-6;
    let outage = (healthy.makespan_s * 64.0).max(1.0);
    let faults = FleetFaultPlan::parse(&format!("burst:r=1-2,at={kill_at},for={outage}")).unwrap();
    assert_eq!(faults.events.len(), 4);

    let unprotected = fleet(vec![ReplicaConfig::default(); 3], wl_spec)
        .with_faults(faults.clone())
        .with_deadline(deadline)
        .try_run(seed)
        .unwrap();
    assert_eq!(unprotected.completed, unprotected.requests, "legacy path loses nothing");
    assert_eq!(unprotected.replica_failures, 2);
    assert!(unprotected.tokens.is_exact(), "{:?}", unprotected.tokens);

    let overload = OverloadConfig::parse(
        "queue-cap=4,frontend-cap=6,retries=2,backoff=0.0002,backoff-cap=0.001,\
         breaker-after=1,cooldown=0.002",
    )
    .unwrap();
    let protected_sim = || {
        fleet(vec![ReplicaConfig::default(); 3], wl_spec)
            .with_faults(faults.clone())
            .with_deadline(deadline)
            .with_overload(overload.clone())
    };
    let p = protected_sim().try_run(seed).unwrap();

    // Exact request ledger: every request is accounted for, shedding is
    // deliberate, bounded, and non-zero under this much overload.
    assert!(p.protected);
    assert_eq!(p.completed + p.shed, p.requests, "request ledger must be exact");
    assert_eq!(
        p.shed,
        p.overload.shed_deadline + p.overload.shed_frontend + p.overload.shed_retries,
        "shed causes must partition the shed count"
    );
    assert!(p.shed > 0, "two dead replicas + bursts must shed something");
    assert!(p.shed < p.requests, "protection must not shed everything");
    assert!(p.completed > 0);
    assert!(p.max_requeues <= 2, "retry budget bounds requeues, got {}", p.max_requeues);
    assert!(
        p.overload.breaker_opens >= 2,
        "both killed replicas must trip their breakers, got {}",
        p.overload.breaker_opens
    );
    assert!(p.tokens.is_exact(), "{:?}", p.tokens);
    let mut sum = TokenLedger::default();
    for rep in &p.replicas {
        assert!(rep.tokens.is_exact(), "{:?}", rep.tokens);
        sum.absorb(&rep.tokens);
    }
    assert_eq!(sum, p.tokens, "fleet ledger is the sum of its replicas");

    // The headline inequalities: shedding the unservable work buys
    // strictly more goodput and a lower completed-request p99 TTFT than
    // queueing everything on the survivor.
    assert!(
        p.goodput_tps > unprotected.goodput_tps,
        "protected goodput {} must beat unprotected {}",
        p.goodput_tps,
        unprotected.goodput_tps
    );
    assert!(
        p.ttft.p99 < unprotected.ttft.p99,
        "protected p99 TTFT {} must beat unprotected {}",
        p.ttft.p99,
        unprotected.ttft.p99
    );

    // Bit-reproducible including every protection decision.
    let q = protected_sim().try_run(seed).unwrap();
    assert_bit_identical(&p, &q).unwrap();
    assert_eq!(p.shed, q.shed);
    assert_eq!(p.overload, q.overload);
}

/// Property: under K overlapping replica failures (replica 0 always
/// survives), the protected fleet keeps the request ledger exact, never
/// exceeds the retry budget, and always completes at least one request.
#[test]
fn correlated_failure_storms_keep_ledgers_exact_and_requeues_bounded() {
    let overload = OverloadConfig::parse("queue-cap=6,frontend-cap=8,retries=2").unwrap();
    assert_property(
        "fleet failure storms",
        0x5702,
        6,
        |rng| {
            let seed = rng.index(10_000) as u64;
            let mut events = Vec::new();
            let k = 1 + rng.index(3); // 1..=3 overlapping failures
            for _ in 0..k {
                let replica = 1 + rng.index(3); // never replica 0
                let at_s = 0.0005 + 0.0005 * rng.f64();
                events.push(FleetEvent::Fail { replica, at_s });
                if rng.index(2) == 0 {
                    events
                        .push(FleetEvent::Recover { replica, at_s: at_s + 0.002 + 0.002 * rng.f64() });
                }
            }
            (seed, events)
        },
        |(seed, events)| {
            let r = fleet(
                vec![ReplicaConfig::default(); 4],
                "bursty:n=24,ia=0.0002,burst=6,every=8,prompt=256-1024,decode=2-6",
            )
            .with_faults(FleetFaultPlan { events: events.clone() })
            .with_overload(overload.clone())
            .try_run(*seed)?;
            if r.completed + r.shed != r.requests {
                return Err(format!(
                    "lost requests: {} + {} != {}",
                    r.completed, r.shed, r.requests
                ));
            }
            if r.completed == 0 {
                return Err("replica 0 survived, something must complete".into());
            }
            if r.max_requeues > 2 {
                return Err(format!("retry budget exceeded: {} requeues", r.max_requeues));
            }
            let mut sum = TokenLedger::default();
            for rep in &r.replicas {
                if !rep.tokens.is_exact() {
                    return Err(format!("replica ledger inexact: {:?}", rep.tokens));
                }
                sum.absorb(&rep.tokens);
            }
            if sum != r.tokens || !r.tokens.is_exact() {
                return Err(format!("fleet ledger broken: {:?} vs sum {:?}", r.tokens, sum));
            }
            Ok(())
        },
        no_shrink,
    );
}

/// Satellite regression: TTFT is the first *successful* prefill. A
/// request whose first prefill is aborted by a replica failure must
/// report a TTFT at least as large as the failed attempt's lifetime —
/// not the aborted attempt's (flattering) first-token time.
#[test]
fn ttft_counts_only_the_successful_prefill_after_a_failure() {
    let wl = "poisson:n=1,ia=0.001,prompt=512-512,decode=8-8";
    let seed = 13;
    let healthy = fleet(vec![ReplicaConfig::default(); 2], wl).try_run(seed).unwrap();
    assert_eq!(healthy.completed, 1);
    let ttft0 = healthy.ttft.max;
    let latency0 = healthy.request_latency.max;
    assert!(latency0 > ttft0, "8 decode steps separate first token from completion");

    // Kill the serving replica (least-queue ties to 0) strictly between
    // the first token and completion: the prefill succeeded, the request
    // did not, so its TTFT clock must restart on the survivor.
    let arrival = Workload::parse(wl).unwrap().generate(&mut Rng::new(seed))[0].arrival_s;
    let kill_at = arrival + (ttft0 + latency0) / 2.0;
    let r = fleet(vec![ReplicaConfig::default(); 2], wl)
        .with_faults(FleetFaultPlan {
            events: vec![FleetEvent::Fail { replica: 0, at_s: kill_at }],
        })
        .try_run(seed)
        .unwrap();
    assert_eq!(r.completed, 1);
    assert_eq!(r.requeued_requests, 1, "the kill must catch the request in flight");
    assert!(
        r.ttft.max >= kill_at - arrival,
        "TTFT {} must cover the failed attempt (killed {}s in)",
        r.ttft.max,
        kill_at - arrival
    );
    assert!(r.ttft.max > ttft0, "restarted TTFT must exceed the aborted attempt's {ttft0}");
}
