"""Layer 2 — JAX MoE model (build-time only; never on the request path).

Defines:

* :func:`moe_layer` — one MoE layer (router -> top-K -> expert SwiGLU ->
  gated combine). The inference-path artifact (``moe_fwd``) routes the
  expert FFN through the **Pallas kernel** (kernels/moe_gemm.py); the
  training path uses the jnp reference (mathematically identical,
  asserted by pytest) because ``pallas_call`` has no autodiff rule.
* :func:`transformer_forward` / :func:`train_step` — a tiny MoE
  transformer (causal attention + MoE FFN) with cross-entropy loss and
  SGD, for the Fig.-5 end-to-end training experiment. ``train_step``
  additionally returns per-expert routed-token counts so the rust
  coordinator can price EP vs LLEP per step.

Everything here is lowered once by ``aot.py`` to HLO text and executed
from rust via PJRT.
"""

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from compile.kernels import moe_gemm, ref

# ---------------------------------------------------------------------------
# Tiny-model geometry (mirrors ModelPreset::Tiny on the rust side).
# ---------------------------------------------------------------------------
VOCAB = 32
D_MODEL = 32
D_FF = 64
N_EXPERTS = 8
TOP_K = 2
N_LAYERS = 2
SEQ = 16
BATCH = 8
LR = 0.05


class LayerParams(NamedTuple):
    wq: jax.Array  # (D, D)
    wk: jax.Array
    wv: jax.Array
    wo: jax.Array
    router: jax.Array  # (D, N)
    w_gate: jax.Array  # (N, D, H)
    w_up: jax.Array  # (N, D, H)
    w_down: jax.Array  # (N, H, D)


class Params(NamedTuple):
    embed: jax.Array  # (V, D)
    layers: tuple  # of LayerParams
    unembed: jax.Array  # (D, V)


def init_params(seed):
    """Initialize the tiny transformer from a scalar seed (f32, truncated)."""
    key = jax.random.PRNGKey(jnp.asarray(seed, jnp.float32).astype(jnp.int32))
    keys = jax.random.split(key, 2 + N_LAYERS * 8)
    s_attn = 1.0 / jnp.sqrt(D_MODEL)
    layers = []
    for i in range(N_LAYERS):
        k = keys[2 + i * 8 : 2 + (i + 1) * 8]
        layers.append(
            LayerParams(
                wq=jax.random.normal(k[0], (D_MODEL, D_MODEL), jnp.float32) * s_attn,
                wk=jax.random.normal(k[1], (D_MODEL, D_MODEL), jnp.float32) * s_attn,
                wv=jax.random.normal(k[2], (D_MODEL, D_MODEL), jnp.float32) * s_attn,
                wo=jax.random.normal(k[3], (D_MODEL, D_MODEL), jnp.float32) * s_attn,
                # Router init models a *post-trained* MoE whose experts have
                # specialized (paper §3.1): layer i's expert (2i+1) column
                # has 10x the weight variance, so its logit dominates the
                # argmax for a large fraction of tokens and the Fig.-5 run
                # starts — like real fine-tuning does — from imbalanced
                # routing. (A uniform additive column bias would cancel
                # against zero-mean activations.)
                router=jax.random.normal(k[4], (D_MODEL, N_EXPERTS), jnp.float32)
                * (0.3 + 3.0 * jax.nn.one_hot((2 * i + 1) % N_EXPERTS, N_EXPERTS))[None, :],
                w_gate=jax.random.normal(k[5], (N_EXPERTS, D_MODEL, D_FF), jnp.float32) * s_attn,
                w_up=jax.random.normal(k[6], (N_EXPERTS, D_MODEL, D_FF), jnp.float32) * s_attn,
                w_down=jax.random.normal(k[7], (N_EXPERTS, D_FF, D_MODEL), jnp.float32)
                * (1.0 / jnp.sqrt(D_FF)),
            )
        )
    return Params(
        embed=jax.random.normal(keys[0], (VOCAB, D_MODEL), jnp.float32) * 0.1,
        layers=tuple(layers),
        unembed=jax.random.normal(keys[1], (D_MODEL, VOCAB), jnp.float32) * s_attn,
    )


def flatten_params(params: Params):
    """Stable flattening used by the AOT interface (rust sees this order)."""
    flat = [params.embed]
    for lp in params.layers:
        flat.extend(list(lp))
    flat.append(params.unembed)
    return flat


def unflatten_params(flat):
    layers = []
    idx = 1
    for _ in range(N_LAYERS):
        layers.append(LayerParams(*flat[idx : idx + 8]))
        idx += 8
    return Params(embed=flat[0], layers=tuple(layers), unembed=flat[idx])


# ---------------------------------------------------------------------------
# MoE layer
# ---------------------------------------------------------------------------
def topk_manual(scores, k):
    """Iterative-argmax top-k.

    ``jax.lax.top_k`` lowers to a ``topk`` HLO instruction that the
    xla_extension 0.5.1 text parser rejects (``largest=true`` attribute);
    k rounds of argmax+mask lower to plain reduce/select ops that
    round-trip cleanly. K is tiny (2-8), so this costs nothing.
    """
    vals, idxs = [], []
    s = scores
    for _ in range(k):
        i = jnp.argmax(s, axis=-1)
        one_hot = jax.nn.one_hot(i, s.shape[-1], dtype=s.dtype)
        vals.append(jnp.sum(scores * one_hot, axis=-1))
        idxs.append(i)
        # mask with a large FINITE value: `one_hot * inf` would produce
        # 0*inf = NaN on unselected entries, and argmax-over-NaN order is
        # not deterministic across jit/eager.
        s = s - one_hot * jnp.asarray(1e30, s.dtype)
    return jnp.stack(vals, axis=-1), jnp.stack(idxs, axis=-1)


def route_topk(x, router_w):
    """Paper Eq. 1-2: softmax router, keep the K highest.

    Args:
      x: ``(T, D)`` tokens; router_w: ``(D, N)``.
    Returns:
      gates ``(T, K)``, indices ``(T, K)`` and counts ``(N,)``.
    """
    scores = jax.nn.softmax(x @ router_w, axis=-1)  # (T, N)
    gates, idx = topk_manual(scores, TOP_K)
    counts = jnp.sum(jax.nn.one_hot(idx, N_EXPERTS, dtype=jnp.float32), axis=(0, 1))
    return gates, idx, counts


def moe_layer(x, lp: LayerParams, use_pallas: bool):
    """One MoE layer over flattened tokens ``x: (T, D)``.

    Dense-mask formulation (every expert sees all tokens with per-token
    mask weights): numerically identical to dispatch-based MoE because
    masked tokens carry zero gate weight. Fine at the tiny geometry, and
    keeps the computation lowerable with static shapes.
    """
    ffn = moe_gemm.swiglu_ffn if use_pallas else ref.swiglu_ffn
    gates, idx, counts = route_topk(x, lp.router)
    # per-expert gate mass per token: (T, N)
    mask = jnp.einsum("tk,tkn->tn", gates, jax.nn.one_hot(idx, N_EXPERTS, dtype=x.dtype))
    out = jnp.zeros_like(x)
    for e in range(N_EXPERTS):
        y = ffn(x, lp.w_gate[e], lp.w_up[e], lp.w_down[e])  # (T, D)
        out = out + mask[:, e : e + 1] * y
    return out, counts


def attention(x, lp: LayerParams):
    """Single-head causal self-attention over ``x: (B, T, D)``."""
    q = x @ lp.wq
    k = x @ lp.wk
    v = x @ lp.wv
    scale = 1.0 / jnp.sqrt(jnp.asarray(D_MODEL, x.dtype))
    att = jnp.einsum("btd,bsd->bts", q, k) * scale
    t = x.shape[1]
    causal = jnp.tril(jnp.ones((t, t), bool))
    att = jnp.where(causal[None], att, -1e9)
    att = jax.nn.softmax(att, axis=-1)
    return jnp.einsum("bts,bsd->btd", att, v) @ lp.wo


def rms_norm(x):
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + 1e-6)


def transformer_forward(params: Params, tokens, use_pallas: bool = False):
    """Forward pass.

    Args:
      params: model parameters.
      tokens: ``(B, T)`` float token ids (cast to int internally so the
        AOT interface stays f32-only).
    Returns:
      logits ``(B, T, V)`` and per-expert counts ``(N,)`` summed over
      layers.
    """
    ids = tokens.astype(jnp.int32)
    x = params.embed[ids]  # (B, T, D)
    b, t, _ = x.shape
    total_counts = jnp.zeros((N_EXPERTS,), jnp.float32)
    for lp in params.layers:
        x = x + attention(rms_norm(x), lp)
        flat = rms_norm(x).reshape(b * t, D_MODEL)
        moe_out, counts = moe_layer(flat, lp, use_pallas)
        x = x + moe_out.reshape(b, t, D_MODEL)
        total_counts = total_counts + counts
    logits = rms_norm(x) @ params.unembed
    return logits, total_counts


def loss_fn(flat_params, x, y):
    params = unflatten_params(flat_params)
    logits, counts = transformer_forward(params, x)
    targets = y.astype(jnp.int32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
    return jnp.mean(nll), counts


@functools.partial(jax.jit)
def train_step(*args):
    """One SGD step. args = (*flat_params, x, y);
    returns (loss, *new_flat_params, expert_counts)."""
    flat_params = list(args[:-2])
    x, y = args[-2], args[-1]
    (loss, counts), grads = jax.value_and_grad(loss_fn, has_aux=True)(flat_params, x, y)
    new_params = [p - LR * g for p, g in zip(flat_params, grads)]
    return (loss.reshape(1), *new_params, counts)


@jax.jit
def moe_fwd(x, router_w, w_gate, w_up, w_down):
    """Standalone MoE layer forward through the **Pallas** kernel — the
    numeric cross-check artifact (rust compares it against its own
    dispatch-compute-combine on identical inputs).

    Args:
      x: ``(T, D)``; router_w ``(D, N)``; stacked expert weights
      ``(N, D, H)/(N, D, H)/(N, H, D)``.
    Returns:
      (out ``(T, D)``, gates ``(T, K)``, indices ``(T, K)`` as f32,
      counts ``(N,)``).
    """
    lp = LayerParams(
        wq=None, wk=None, wv=None, wo=None,
        router=router_w, w_gate=w_gate, w_up=w_up, w_down=w_down,
    )
    out, counts = moe_layer(x, lp, use_pallas=True)
    gates, idx, _ = route_topk(x, router_w)
    return out, gates, idx.astype(jnp.float32), counts
