"""Layer 1 — Pallas kernels for the MoE hot spot.

Two kernels:

* :func:`swiglu_ffn` — the per-expert SwiGLU FFN
  ``(silu(x Wg) * (x Wu)) Wd``, tiled over the token dimension. This is
  the GEMM trio the paper's Eq. 3 prices and that LLEP schedules across
  devices.
* :func:`gated_combine` — the top-K combine
  ``out[b] = sum_k gates[b, k] * y[b, k]`` (the reverse-sorted
  reduction at the end of Alg. 1/4).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's CUDA
grouped-GEMM tiles by threadblock over tokens; on TPU the analogue is a
grid over token blocks with the weight matrices resident in VMEM per grid
step, feeding the MXU with ``(block_b, D) @ (D, H)`` products. BlockSpec
expresses the HBM->VMEM schedule. ``interpret=True`` everywhere: the CPU
PJRT plugin cannot execute Mosaic custom-calls, and interpret mode lowers
to plain HLO that both pytest and the rust runtime can run. Real-TPU
VMEM/MXU estimates are documented in EXPERIMENTS.md.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _swiglu_kernel(x_ref, wg_ref, wu_ref, wd_ref, o_ref):
    """One grid step: a (block_b, D) token tile through the SwiGLU trio."""
    x = x_ref[...]
    g = jnp.dot(x, wg_ref[...])
    u = jnp.dot(x, wu_ref[...])
    a = (g * (1.0 / (1.0 + jnp.exp(-g)))) * u  # silu(g) * u
    o_ref[...] = jnp.dot(a, wd_ref[...])


def pick_block_b(batch: int) -> int:
    """Token-tile size: smallest power of two >= 8 dividing the batch,
    capped at 128 (VMEM budget at paper geometry; see EXPERIMENTS.md)."""
    for cand in (128, 64, 32, 16, 8):
        if batch % cand == 0:
            return cand
    return batch  # tiny/odd batches: single tile


@functools.partial(jax.jit, static_argnames=("block_b",))
def swiglu_ffn(x, w_gate, w_up, w_down, block_b=None):
    """Pallas SwiGLU expert FFN.

    Args:
      x: ``(B, D)`` token tile.
      w_gate, w_up: ``(D, H)``; w_down: ``(H, D)``.
      block_b: token-tile size (defaults to :func:`pick_block_b`).
    Returns:
      ``(B, D)``.
    """
    b, d = x.shape
    h = w_gate.shape[1]
    assert w_gate.shape == (d, h) and w_up.shape == (d, h) and w_down.shape == (h, d)
    bb = block_b or pick_block_b(b)
    grid = (b // bb,) if b % bb == 0 else (1,)
    if b % bb != 0:
        bb = b
    return pl.pallas_call(
        _swiglu_kernel,
        grid=grid,
        in_specs=[
            # token tile streams HBM->VMEM per grid step
            pl.BlockSpec((bb, d), lambda i: (i, 0)),
            # weights resident in VMEM across all grid steps
            pl.BlockSpec((d, h), lambda i: (0, 0)),
            pl.BlockSpec((d, h), lambda i: (0, 0)),
            pl.BlockSpec((h, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bb, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, d), x.dtype),
        interpret=True,
    )(x, w_gate, w_up, w_down)


def _combine_kernel(y_ref, g_ref, o_ref):
    """One grid step: gate-weighted sum over the K axis for a token tile."""
    y = y_ref[...]  # (bb, K, D)
    g = g_ref[...]  # (bb, K)
    o_ref[...] = jnp.sum(y * g[:, :, None], axis=1)


@jax.jit
def gated_combine(y, gates):
    """Pallas top-K combine: ``(B, K, D), (B, K) -> (B, D)``."""
    b, k, d = y.shape
    assert gates.shape == (b, k)
    bb = pick_block_b(b)
    return pl.pallas_call(
        _combine_kernel,
        grid=(b // bb,) if b % bb == 0 else (1,),
        in_specs=[
            pl.BlockSpec((bb, k, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((bb, k), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bb, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, d), y.dtype),
        interpret=True,
    )(y, gates)


def _swiglu_htiled_kernel(x_ref, wg_ref, wu_ref, wd_ref, o_ref, acc_ref):
    """Grid step (i, j): token tile i x H-tile j.

    The paper-geometry weights (D=H=2880, bf16, 3 mats ~ 47 MiB) exceed a
    TPU core's ~16 MiB VMEM, so the full-weight schedule of
    :func:`swiglu_ffn` cannot be resident. This variant streams H-tiles:
    grid (B/bb, H/bh); step (i, j) computes the (bb, bh) slice of
    silu(x Wg) * (x Wu) and accumulates its down-projection into the
    output accumulator. VMEM per step = bb*d + 2*d*bh + bh*d + bb*d —
    bounded by the tile sizes, not by H.
    """
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    g = jnp.dot(x, wg_ref[...])  # (bb, bh) slice of the H dim
    u = jnp.dot(x, wu_ref[...])
    a = (g * (1.0 / (1.0 + jnp.exp(-g)))) * u
    acc_ref[...] += jnp.dot(a, wd_ref[...])  # partial down-projection

    @pl.when(j == pl.num_programs(1) - 1)
    def _finalize():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("block_b", "block_h"))
def swiglu_ffn_htiled(x, w_gate, w_up, w_down, block_b=None, block_h=None):
    """H-tiled Pallas SwiGLU FFN (paper-geometry schedule; see
    :func:`_swiglu_htiled_kernel`). Numerically identical to
    :func:`swiglu_ffn` — asserted by pytest."""
    b, d = x.shape
    h = w_gate.shape[1]
    bb = block_b or pick_block_b(b)
    bh = block_h or pick_block_b(h)
    if b % bb != 0:
        bb = b
    if h % bh != 0:
        bh = h
    grid = (b // bb, h // bh)
    return pl.pallas_call(
        _swiglu_htiled_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, d), lambda i, j: (i, 0)),
            pl.BlockSpec((d, bh), lambda i, j: (0, j)),
            pl.BlockSpec((d, bh), lambda i, j: (0, j)),
            pl.BlockSpec((bh, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bb, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, d), x.dtype),
        scratch_shapes=[pltpu_scratch(bb, d, x.dtype)],
        interpret=True,
    )(x, w_gate, w_up, w_down)


def pltpu_scratch(bb, d, dtype):
    """VMEM accumulator scratch (interpret-mode compatible)."""
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM((bb, d), dtype)


def vmem_footprint_bytes(block_b: int, d: int, h: int, dtype_bytes: int = 4) -> int:
    """Estimated VMEM residency of one grid step of :func:`swiglu_ffn`:
    token tile + three weight mats + activations + output tile."""
    tile = block_b * d
    weights = 2 * d * h + h * d
    acts = 2 * block_b * h
    out = block_b * d
    return (tile + weights + acts + out) * dtype_bytes


def vmem_footprint_htiled_bytes(
    block_b: int, d: int, block_h: int, dtype_bytes: int = 4
) -> int:
    """VMEM residency of one grid step of :func:`swiglu_ffn_htiled` —
    independent of the full H, which is what makes paper geometry fit."""
    tile = block_b * d
    weights = 2 * d * block_h + block_h * d
    acts = 2 * block_b * block_h
    acc = block_b * d
    out = block_b * d
    return (tile + weights + acts + acc + out) * dtype_bytes
