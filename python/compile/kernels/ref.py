"""Pure-jnp oracles for the Pallas kernels.

These are the correctness references: pytest checks every Pallas kernel
against the matching function here across shapes and dtypes, and the JAX
model (model.py) uses these in its differentiable paths (the Pallas
forward kernel is mathematically identical — asserted by the tests).
"""

import jax.numpy as jnp


def silu(x):
    """x * sigmoid(x) (numerically plain; matches the kernel)."""
    return x * (1.0 / (1.0 + jnp.exp(-x)))


def swiglu_ffn(x, w_gate, w_up, w_down):
    """SwiGLU expert FFN: ``(silu(x @ Wg) * (x @ Wu)) @ Wd``.

    Args:
      x: ``(B, D)`` tokens.
      w_gate, w_up: ``(D, H)``.
      w_down: ``(H, D)``.
    Returns:
      ``(B, D)``.
    """
    g = x @ w_gate
    u = x @ w_up
    return (silu(g) * u) @ w_down


def gated_combine(y, gates):
    """Combine top-K expert outputs: ``sum_k gates[:, k] * y[:, k, :]``.

    Args:
      y: ``(B, K, D)`` per-slot expert outputs.
      gates: ``(B, K)`` routing weights.
    Returns:
      ``(B, D)``.
    """
    return jnp.einsum("bkd,bk->bd", y, gates)
