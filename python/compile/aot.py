"""AOT compiler: lowers the JAX/Pallas entry points to HLO **text** and
writes ``artifacts/*.hlo.txt`` + ``manifest.json`` for the rust runtime.

HLO text (NOT ``lowered.compile()`` / serialized protos) is the
interchange format: jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids that the xla crate's xla_extension 0.5.1 rejects; the
text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Usage: ``python -m compile.aot --out ../artifacts`` (from python/) or via
``make artifacts``.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model
from compile.kernels import moe_gemm

# Token buckets for the expert-FFN artifacts (rust pads to the nearest).
FFN_BUCKETS = (64, 256, 1024)
MOE_FWD_TOKENS = 128


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(*dims, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(dims, dtype)


def lower_entry(fn, example_args):
    return jax.jit(fn).lower(*example_args)


def shapes_of(args):
    return [list(a.shape) for a in args]


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts", help="output directory")
    args = parser.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {"artifacts": {}}

    def emit(name, fn, example_args, meta=None, out_shapes=None):
        lowered = lower_entry(fn, example_args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out, fname), "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": fname,
            "inputs": shapes_of(example_args),
            "outputs": out_shapes or [],
            "meta": meta or {},
        }
        print(f"  {name:<18} {len(text):>9} chars  inputs={shapes_of(example_args)}")

    d, h = model.D_MODEL, model.D_FF

    # --- Layer-1 Pallas expert FFN, bucketed over token count -------------
    for b in FFN_BUCKETS:
        emit(
            f"expert_ffn_b{b}",
            lambda x, wg, wu, wd: moe_gemm.swiglu_ffn(x, wg, wu, wd),
            (spec(b, d), spec(d, h), spec(d, h), spec(h, d)),
            meta={"bucket": b, "d_model": d, "d_ff": h},
            out_shapes=[[b, d]],
        )

    # --- H-tiled kernel variant (paper-geometry VMEM schedule) -------------
    emit(
        "expert_ffn_htiled_b256",
        lambda x, wg, wu, wd: moe_gemm.swiglu_ffn_htiled(x, wg, wu, wd),
        (spec(256, d), spec(d, h), spec(d, h), spec(h, d)),
        meta={"bucket": 256, "d_model": d, "d_ff": h, "htiled": 1},
        out_shapes=[[256, d]],
    )

    # --- Pallas gated combine ---------------------------------------------
    emit(
        "gated_combine",
        moe_gemm.gated_combine,
        (spec(MOE_FWD_TOKENS, model.TOP_K, d), spec(MOE_FWD_TOKENS, model.TOP_K)),
        meta={"tokens": MOE_FWD_TOKENS, "top_k": model.TOP_K},
        out_shapes=[[MOE_FWD_TOKENS, d]],
    )

    # --- Full MoE layer forward (numeric cross-check artifact) -------------
    n = model.N_EXPERTS
    emit(
        "moe_fwd",
        model.moe_fwd,
        (spec(MOE_FWD_TOKENS, d), spec(d, n), spec(n, d, h), spec(n, d, h), spec(n, h, d)),
        meta={
            "tokens": MOE_FWD_TOKENS,
            "num_experts": n,
            "top_k": model.TOP_K,
            "d_model": d,
            "d_ff": h,
        },
        out_shapes=[
            [MOE_FWD_TOKENS, d],
            [MOE_FWD_TOKENS, model.TOP_K],
            [MOE_FWD_TOKENS, model.TOP_K],
            [n],
        ],
    )

    # --- Training: init + step ---------------------------------------------
    params = model.init_params(0.0)
    flat = model.flatten_params(params)
    param_specs = tuple(spec(*p.shape) for p in flat)

    emit(
        "init_params",
        lambda seed: tuple(model.flatten_params(model.init_params(seed))),
        (spec(),),
        meta={"num_params": len(flat)},
        out_shapes=[list(p.shape) for p in flat],
    )

    emit(
        "train_step",
        model.train_step,
        param_specs + (spec(model.BATCH, model.SEQ), spec(model.BATCH, model.SEQ)),
        meta={
            "num_params": len(flat),
            "batch": model.BATCH,
            "seq": model.SEQ,
            "vocab": model.VOCAB,
            "num_experts": model.N_EXPERTS,
            "top_k": model.TOP_K,
            "lr": model.LR,
        },
        out_shapes=[[1]] + [list(p.shape) for p in flat] + [[model.N_EXPERTS]],
    )

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {len(manifest['artifacts'])} artifacts + manifest to {args.out}")


if __name__ == "__main__":
    main()
