"""AOT pipeline tests: HLO-text emission and manifest structure.

The full `make artifacts` run is exercised end-to-end by the rust
integration tests; here we check the lowering helpers directly on one
cheap entry point (so pytest stays fast) and validate the interchange
invariants the rust loader depends on.
"""

import json

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model
from compile.kernels import moe_gemm


@pytest.fixture(scope="module")
def kernel_hlo_text():
    lowered = aot.lower_entry(
        lambda x, wg, wu, wd: moe_gemm.swiglu_ffn(x, wg, wu, wd),
        (aot.spec(64, 8), aot.spec(8, 16), aot.spec(8, 16), aot.spec(16, 8)),
    )
    return aot.to_hlo_text(lowered)


def test_hlo_text_is_parseable_hlo(kernel_hlo_text):
    # Must be HLO *text* — the interchange contract with xla_extension
    # 0.5.1 (see aot.py docstring).
    assert kernel_hlo_text.startswith("HloModule")
    assert "ENTRY" in kernel_hlo_text
    # return_tuple=True => the root computation returns a tuple
    assert "tuple" in kernel_hlo_text


def test_hlo_has_no_unparseable_ops(kernel_hlo_text):
    # Ops known to break the 0.5.1 text parser must not appear.
    assert "topk(" not in kernel_hlo_text
    assert "mosaic" not in kernel_hlo_text.lower()


def test_train_step_lowers_without_topk():
    # The manual argmax top-k keeps `topk(` out of the training HLO too.
    params = model.init_params(0.0)
    flat = model.flatten_params(params)
    specs = tuple(aot.spec(*p.shape) for p in flat) + (
        aot.spec(model.BATCH, model.SEQ),
        aot.spec(model.BATCH, model.SEQ),
    )
    lowered = aot.lower_entry(model.train_step, specs)
    text = aot.to_hlo_text(lowered)
    assert "topk(" not in text
    assert text.startswith("HloModule")


def test_shapes_of():
    args = (aot.spec(2, 3), aot.spec(5))
    assert aot.shapes_of(args) == [[2, 3], [5]]


def test_manifest_written_structure(tmp_path, monkeypatch):
    # Run main() with a stubbed emit set? Cheaper: emit one artifact
    # manually through the same code path used by main().
    lowered = aot.lower_entry(
        lambda x: (x + 1.0,), (aot.spec(4, 4),)
    )
    text = aot.to_hlo_text(lowered)
    f = tmp_path / "unit.hlo.txt"
    f.write_text(text)
    manifest = {
        "artifacts": {
            "unit": {"file": "unit.hlo.txt", "inputs": [[4, 4]], "outputs": [[4, 4]], "meta": {}}
        }
    }
    (tmp_path / "manifest.json").write_text(json.dumps(manifest))
    # structure parses back and file exists
    loaded = json.loads((tmp_path / "manifest.json").read_text())
    assert loaded["artifacts"]["unit"]["file"] == "unit.hlo.txt"
    assert (tmp_path / loaded["artifacts"]["unit"]["file"]).exists()


def test_buckets_cover_training_batch():
    # The runtime pads token groups to these buckets; they must cover the
    # tiny model's largest realistic group (B*T tokens on one expert).
    assert max(aot.FFN_BUCKETS) >= model.BATCH * model.SEQ
    assert sorted(aot.FFN_BUCKETS) == list(aot.FFN_BUCKETS)
