"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

This is the CORE correctness signal for the kernel layer. Shapes and
dtypes are swept hypothesis-style (seeded random draws across the shape
space) and compared with assert_allclose.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile.kernels import moe_gemm, ref


def rand(key, *shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


def tol(dtype):
    # bf16 carries ~8 mantissa bits; matmul accumulation over H compounds it.
    return dict(rtol=2e-1, atol=5e-1) if dtype == jnp.bfloat16 else dict(rtol=2e-5, atol=2e-5)


# --------------------------------------------------------------------------
# swiglu_ffn
# --------------------------------------------------------------------------
@pytest.mark.parametrize("b", [1, 3, 8, 64, 100, 256])
@pytest.mark.parametrize("d,h", [(8, 16), (32, 64)])
def test_swiglu_matches_ref_shapes(b, d, h):
    k = jax.random.split(jax.random.PRNGKey(b * 1000 + d), 4)
    x = rand(k[0], b, d)
    wg, wu = rand(k[1], d, h), rand(k[2], d, h)
    wd = rand(k[3], h, d)
    got = moe_gemm.swiglu_ffn(x, wg, wu, wd)
    want = ref.swiglu_ffn(x, wg, wu, wd)
    assert got.shape == (b, d)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **tol(jnp.float32))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_swiglu_dtypes(dtype):
    k = jax.random.split(jax.random.PRNGKey(7), 4)
    x = rand(k[0], 16, 8, dtype=dtype)
    wg, wu = rand(k[1], 8, 12, dtype=dtype), rand(k[2], 8, 12, dtype=dtype)
    wd = rand(k[3], 12, 8, dtype=dtype)
    got = moe_gemm.swiglu_ffn(x, wg, wu, wd)
    want = ref.swiglu_ffn(
        x.astype(jnp.float32), wg.astype(jnp.float32),
        wu.astype(jnp.float32), wd.astype(jnp.float32),
    )
    assert got.dtype == dtype
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want), **tol(dtype)
    )


def test_swiglu_hypothesis_sweep():
    """Seeded random sweep over the (B, D, H, block) space."""
    rng = np.random.RandomState(0)
    for trial in range(25):
        b = int(rng.choice([1, 2, 5, 8, 16, 24, 64, 96]))
        d = int(rng.choice([4, 8, 16, 32]))
        h = int(rng.choice([4, 8, 24, 48]))
        k = jax.random.split(jax.random.PRNGKey(trial), 4)
        x = rand(k[0], b, d)
        wg, wu, wd = rand(k[1], d, h), rand(k[2], d, h), rand(k[3], h, d)
        got = moe_gemm.swiglu_ffn(x, wg, wu, wd)
        want = ref.swiglu_ffn(x, wg, wu, wd)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=3e-5, atol=3e-5,
            err_msg=f"trial {trial}: b={b} d={d} h={h}",
        )


def test_swiglu_explicit_block_sizes():
    k = jax.random.split(jax.random.PRNGKey(3), 4)
    x = rand(k[0], 64, 16)
    wg, wu, wd = rand(k[1], 16, 32), rand(k[2], 16, 32), rand(k[3], 32, 16)
    want = ref.swiglu_ffn(x, wg, wu, wd)
    for bb in (8, 16, 32, 64):
        got = moe_gemm.swiglu_ffn(x, wg, wu, wd, block_b=bb)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_swiglu_zero_input_zero_output():
    d, h = 8, 16
    k = jax.random.split(jax.random.PRNGKey(9), 3)
    got = moe_gemm.swiglu_ffn(
        jnp.zeros((4, d)), rand(k[0], d, h), rand(k[1], d, h), rand(k[2], h, d)
    )
    np.testing.assert_array_equal(np.asarray(got), 0.0)


def test_pick_block_b():
    assert moe_gemm.pick_block_b(1024) == 128
    assert moe_gemm.pick_block_b(64) == 64
    assert moe_gemm.pick_block_b(24) == 8
    assert moe_gemm.pick_block_b(7) == 7  # odd: single tile


def test_vmem_footprint_monotone():
    small = moe_gemm.vmem_footprint_bytes(8, 64, 128)
    big = moe_gemm.vmem_footprint_bytes(128, 64, 128)
    assert big > small
    # paper-geometry sanity: fits in 16 MiB VMEM at block_b=128, bf16
    paper = moe_gemm.vmem_footprint_bytes(128, 2880, 2880, dtype_bytes=2)
    assert paper < 64 * 2**20  # documented in EXPERIMENTS.md


# --------------------------------------------------------------------------
# swiglu_ffn_htiled (paper-geometry schedule: H streamed in tiles)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("b,d,h", [(8, 8, 16), (64, 16, 32), (32, 32, 64)])
def test_htiled_matches_ref(b, d, h):
    k = jax.random.split(jax.random.PRNGKey(b + d + h), 4)
    x = rand(k[0], b, d)
    wg, wu, wd = rand(k[1], d, h), rand(k[2], d, h), rand(k[3], h, d)
    got = moe_gemm.swiglu_ffn_htiled(x, wg, wu, wd)
    want = ref.swiglu_ffn(x, wg, wu, wd)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("bh", [4, 8, 16, 32])
def test_htiled_block_h_sweep(bh):
    k = jax.random.split(jax.random.PRNGKey(bh), 4)
    x = rand(k[0], 16, 8)
    wg, wu, wd = rand(k[1], 8, 32), rand(k[2], 8, 32), rand(k[3], 32, 8)
    got = moe_gemm.swiglu_ffn_htiled(x, wg, wu, wd, block_b=8, block_h=bh)
    want = ref.swiglu_ffn(x, wg, wu, wd)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-5, atol=3e-5)


def test_htiled_equals_full_kernel():
    k = jax.random.split(jax.random.PRNGKey(77), 4)
    x = rand(k[0], 64, 16)
    wg, wu, wd = rand(k[1], 16, 64), rand(k[2], 16, 64), rand(k[3], 64, 16)
    a = moe_gemm.swiglu_ffn(x, wg, wu, wd)
    b = moe_gemm.swiglu_ffn_htiled(x, wg, wu, wd)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=2e-5)


def test_htiled_vmem_fits_paper_geometry():
    # The point of the schedule: paper geometry (D=H=2880, bf16) fits the
    # ~16 MiB/core VMEM budget with bh=512, while the full-weight
    # schedule does not.
    full = moe_gemm.vmem_footprint_bytes(128, 2880, 2880, dtype_bytes=2)
    tiled = moe_gemm.vmem_footprint_htiled_bytes(128, 2880, 512, dtype_bytes=2)
    assert full > 16 * 2**20
    assert tiled < 16 * 2**20
    # and shrinking the tile shrinks the footprint
    assert moe_gemm.vmem_footprint_htiled_bytes(128, 2880, 256, 2) < tiled


# --------------------------------------------------------------------------
# gated_combine
# --------------------------------------------------------------------------
@pytest.mark.parametrize("b,k_,d", [(1, 1, 4), (8, 2, 16), (64, 4, 32), (100, 2, 8)])
def test_gated_combine_matches_ref(b, k_, d):
    keys = jax.random.split(jax.random.PRNGKey(b + k_ + d), 2)
    y = rand(keys[0], b, k_, d)
    g = jax.nn.softmax(rand(keys[1], b, k_), axis=-1)
    got = moe_gemm.gated_combine(y, g)
    want = ref.gated_combine(y, g)
    assert got.shape == (b, d)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_gated_combine_zero_gates():
    y = rand(jax.random.PRNGKey(1), 8, 2, 4)
    got = moe_gemm.gated_combine(y, jnp.zeros((8, 2)))
    np.testing.assert_array_equal(np.asarray(got), 0.0)


def test_gated_combine_one_hot_selects():
    y = rand(jax.random.PRNGKey(2), 8, 3, 4)
    g = jnp.zeros((8, 3)).at[:, 1].set(1.0)
    got = moe_gemm.gated_combine(y, g)
    np.testing.assert_allclose(np.asarray(got), np.asarray(y[:, 1, :]), rtol=1e-6)
