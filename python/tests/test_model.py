"""L2 correctness: model shapes, routing semantics, training step."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile import model


@pytest.fixture(scope="module")
def params():
    return model.init_params(0.0)


def make_batch(seed=0):
    rng = np.random.RandomState(seed)
    x = rng.randint(0, model.VOCAB, size=(model.BATCH, model.SEQ)).astype(np.float32)
    y = rng.randint(0, model.VOCAB, size=(model.BATCH, model.SEQ)).astype(np.float32)
    return jnp.asarray(x), jnp.asarray(y)


def test_init_params_shapes(params):
    flat = model.flatten_params(params)
    assert len(flat) == 2 + model.N_LAYERS * 8
    assert params.embed.shape == (model.VOCAB, model.D_MODEL)
    assert params.layers[0].w_gate.shape == (model.N_EXPERTS, model.D_MODEL, model.D_FF)
    # deterministic given the seed
    flat2 = model.flatten_params(model.init_params(0.0))
    for a, b in zip(flat, flat2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # different seed differs
    flat3 = model.flatten_params(model.init_params(1.0))
    assert any(
        not np.array_equal(np.asarray(a), np.asarray(b)) for a, b in zip(flat, flat3)
    )


def test_flatten_roundtrip(params):
    flat = model.flatten_params(params)
    back = model.unflatten_params(flat)
    np.testing.assert_array_equal(np.asarray(back.embed), np.asarray(params.embed))
    np.testing.assert_array_equal(
        np.asarray(back.layers[1].router), np.asarray(params.layers[1].router)
    )


def test_forward_shapes_and_counts(params):
    x, _ = make_batch()
    logits, counts = model.transformer_forward(params, x)
    assert logits.shape == (model.BATCH, model.SEQ, model.VOCAB)
    assert counts.shape == (model.N_EXPERTS,)
    # every (token, layer) contributes K routed slots
    total = model.BATCH * model.SEQ * model.TOP_K * model.N_LAYERS
    assert float(jnp.sum(counts)) == pytest.approx(total)
    assert bool(jnp.all(counts >= 0))


def test_route_topk_valid(params):
    x = jax.random.normal(jax.random.PRNGKey(0), (10, model.D_MODEL))
    gates, idx, counts = model.route_topk(x, params.layers[0].router)
    assert gates.shape == (10, model.TOP_K)
    assert idx.shape == (10, model.TOP_K)
    assert bool(jnp.all(idx >= 0)) and bool(jnp.all(idx < model.N_EXPERTS))
    # top-k of softmax: gates descending and in (0, 1]
    assert bool(jnp.all(gates[:, 0] >= gates[:, 1]))
    assert bool(jnp.all(gates > 0)) and bool(jnp.all(gates <= 1.0))
    assert float(jnp.sum(counts)) == pytest.approx(10 * model.TOP_K)


def test_moe_layer_pallas_matches_ref(params):
    """The inference path (Pallas) equals the training path (jnp ref)."""
    lp = params.layers[0]
    x = jax.random.normal(jax.random.PRNGKey(1), (64, model.D_MODEL))
    out_pallas, counts_p = model.moe_layer(x, lp, use_pallas=True)
    out_ref, counts_r = model.moe_layer(x, lp, use_pallas=False)
    np.testing.assert_allclose(
        np.asarray(out_pallas), np.asarray(out_ref), rtol=3e-5, atol=3e-5
    )
    np.testing.assert_array_equal(np.asarray(counts_p), np.asarray(counts_r))


def test_train_step_reduces_loss(params):
    flat = model.flatten_params(params)
    x, y = make_batch(1)
    # structured task: y = f(x) deterministic
    y = jnp.asarray((3 * np.asarray(x) + 1) % model.VOCAB, jnp.float32)
    losses = []
    for step in range(30):
        out = model.train_step(*flat, x, y)
        loss, flat, counts = out[0], list(out[1 : 1 + len(flat)]), out[-1]
        losses.append(float(loss[0]))
    assert losses[-1] < losses[0] * 0.9, f"loss did not drop: {losses[0]} -> {losses[-1]}"
    assert counts.shape == (model.N_EXPERTS,)


def test_train_step_param_arity(params):
    flat = model.flatten_params(params)
    x, y = make_batch(2)
    out = model.train_step(*flat, x, y)
    assert len(out) == 1 + len(flat) + 1
    assert out[0].shape == (1,)
    for p, new_p in zip(flat, out[1:-1]):
        assert p.shape == new_p.shape


def test_moe_fwd_artifact_fn(params):
    lp = params.layers[0]
    x = jax.random.normal(jax.random.PRNGKey(3), (model.BATCH * model.SEQ, model.D_MODEL))
    out, gates, idx, counts = model.moe_fwd(x, lp.router, lp.w_gate, lp.w_up, lp.w_down)
    assert out.shape == x.shape
    assert gates.shape == (x.shape[0], model.TOP_K)
    assert idx.shape == (x.shape[0], model.TOP_K)
    assert float(jnp.sum(counts)) == pytest.approx(x.shape[0] * model.TOP_K)
    # out must match the ref-path moe_layer
    ref_out, _ = model.moe_layer(x, lp, use_pallas=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out), rtol=3e-5, atol=3e-5)
