//! Quickstart: plan + simulate one imbalanced MoE step under standard EP
//! and LLEP, then verify exactness with real numerics on the tiny model.
//!
//! Run: `cargo run --release --example quickstart`

use llep::exec::{run_step_real, NativeCompute};
use llep::metrics::{format_bytes, format_secs};
use llep::moe::{forward_reference, route, MoeLayer};
use llep::prelude::*;
use llep::tensor::Mat;

fn main() {
    // ---------------------------------------------------------------
    // Part 1 — paper-scale simulation (gpt-oss-120b layer on 8x H200).
    // ---------------------------------------------------------------
    let model = ModelConfig::preset(ModelPreset::GptOss120b);
    let system = SystemConfig::preset(SystemPreset::H200x8);
    let engine = Engine::modeled(model.clone(), system);

    let mut rng = Rng::new(0);
    // 80% of routed load concentrated into 4 experts (all on device 0).
    let lm = Scenario::concentrated(0.80, 4).generate_loads(&model, 8, 32_768, &mut rng);

    let ep = engine.run_step_loads(&lm, &PlannerKind::StandardEp);
    let ll = engine.run_step_loads(&lm, &PlannerKind::llep_default());

    println!("gpt-oss-120b MoE layer, P=8, 32K tokens/device, 80% into 4 experts");
    println!(
        "  standard EP : latency {}  peak mem {}",
        format_secs(ep.latency_s),
        format_bytes(ep.max_peak_bytes())
    );
    println!(
        "  LLEP        : latency {}  peak mem {}  ({} weight transfers)",
        format_secs(ll.latency_s),
        format_bytes(ll.max_peak_bytes()),
        ll.weight_transfers
    );
    println!(
        "  speedup {:.2}x, memory {:.2}x lower\n",
        ep.latency_s / ll.latency_s,
        ep.max_peak_bytes() as f64 / ll.max_peak_bytes() as f64
    );

    // ---------------------------------------------------------------
    // Part 2 — exactness on real numerics (tiny model, native GEMMs).
    // ---------------------------------------------------------------
    let tiny = ModelConfig::preset(ModelPreset::Tiny);
    let sys4 = SystemConfig::preset(SystemPreset::CpuSim4);
    let engine = Engine::modeled(tiny.clone(), sys4);
    let layer = MoeLayer::random(&tiny, &mut rng);
    let xs: Vec<Mat> = (0..4).map(|_| Mat::randn(32, tiny.d_model, 0.5, &mut rng)).collect();
    let routing = route(&layer, &xs); // real top-K router

    let reference = forward_reference(&layer, &xs, &routing);
    let step = run_step_real(
        &engine,
        &layer,
        &xs,
        &routing,
        &PlannerKind::llep_default(),
        &NativeCompute,
    )
    .expect("real step");
    let max_diff = reference
        .iter()
        .zip(&step.outputs)
        .flat_map(|(a, b)| a.data.iter().zip(&b.data).map(|(x, y)| (x - y).abs()))
        .fold(0f32, f32::max);
    println!("exactness check (LLEP vs single-device reference): max |diff| = {max_diff:.2e}");
    assert!(max_diff < 1e-4, "LLEP must be an exact MoE computation");
    println!("quickstart OK");
}
