//! End-to-end training driver (the Fig.-5 experiment, and the proof that
//! all three layers compose):
//!
//!   Layer 1  Pallas SwiGLU kernel ──┐
//!   Layer 2  JAX tiny MoE transformer (fwd+bwd+SGD) ── AOT → HLO text
//!   Layer 3  this binary: loads the artifact via PJRT, owns the training
//!            loop, prices each step under EP vs LLEP from the returned
//!            per-expert routing counts.
//!
//! Trains a tiny MoE transformer for a few hundred steps on a synthetic
//! next-token corpus and logs the loss curve plus both virtual wall
//! clocks. Requires `make artifacts`.
//!
//! Run: `cargo run --release --example e2e_train -- [steps]`

use llep::exec::Engine;
use llep::metrics::format_secs;
use llep::prelude::*;
use llep::runtime::Runtime;
use llep::trainer::Trainer;

fn main() {
    let steps: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(300);
    let dir = Runtime::default_dir();
    let rt = match Runtime::open(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("cannot open artifacts at {dir:?}: {e:#}");
            eprintln!("run `make artifacts` first");
            std::process::exit(1);
        }
    };
    println!("PJRT platform: {} | {} artifacts loaded", rt.platform(), rt.len());

    let mut trainer = Trainer::new(&rt, 0.0).expect("trainer init (init_params artifact)");
    println!(
        "tiny MoE transformer: vocab={} seq={} batch={} experts={}\n",
        trainer.vocab, trainer.seq, trainer.batch, trainer.num_experts
    );

    // Virtual testbed for pricing the MoE layers of each step.
    let engine = Engine::modeled(
        ModelConfig::preset(ModelPreset::Tiny),
        SystemConfig::preset(SystemPreset::CpuSim4),
    );

    let mut rng = Rng::new(42);
    println!("step   loss     wall(EP)     wall(LLEP)   measured/step");
    let curve = trainer
        .run_curve(steps, &engine, &mut rng, |p| {
            if p.step % 20 == 0 || p.step + 1 == steps {
                println!(
                    "{:<6} {:<8.4} {:<12} {:<12} {}",
                    p.step,
                    p.loss,
                    format_secs(p.wall_ep_s),
                    format_secs(p.wall_llep_s),
                    format_secs(p.measured_step_s)
                );
            }
        })
        .expect("training loop");

    let first = curve.first().unwrap();
    let last = curve.last().unwrap();
    println!(
        "\nloss {:.4} -> {:.4} over {} steps (must decrease)",
        first.loss, last.loss, steps
    );
    assert!(
        last.loss < first.loss,
        "training diverged: {} -> {}",
        first.loss,
        last.loss
    );
    println!(
        "virtual MoE wall-clock: EP {} vs LLEP {}  ({:.2}x)",
        format_secs(last.wall_ep_s),
        format_secs(last.wall_llep_s),
        last.wall_ep_s / last.wall_llep_s
    );

    // Fig. 5: the same loss curve against the two wall clocks.
    let mut plot = llep::metrics::chart::SeriesPlot::new(
        "Fig 5 — loss vs wall-clock seconds  (E = standard EP, L = LLEP)",
    );
    plot.series('E', curve.iter().map(|p| (p.wall_ep_s, p.loss as f64)).collect());
    plot.series('L', curve.iter().map(|p| (p.wall_llep_s, p.loss as f64)).collect());
    println!("\n{}", plot.render());
    println!("e2e_train OK");
}
