//! Depth-varying imbalance: every MoE layer of a model concentrates load
//! on a *different* expert (paper §3.1 / Fig. 3a measures per-layer
//! hotspots), so no static placement fixes all layers at once — but LLEP
//! replans per layer, and the multi-layer engine pipelines that planning
//! behind execution ([`llep::exec::Engine::run_model`]).
//!
//! Run: `cargo run --release --example depth_imbalance`

use llep::metrics::{format_bytes, format_secs, model_report_table, Table};
use llep::prelude::*;

fn main() {
    let model = ModelConfig::preset(ModelPreset::GptOss20b); // 24 MoE layers
    let engine = Engine::modeled(model.clone(), SystemConfig::preset(SystemPreset::H200x8));

    // Layer i favours expert (7i + 11) mod N at ~45% of the routed load,
    // with per-batch drift — depth-varying imbalance.
    let profile = DepthProfile::varying(&model, 0.45, 0.25);
    let mut rng = Rng::new(0);
    let lms = profile.generate_loads(&model, 8, 16_384, &mut rng);

    println!(
        "{} — {} MoE layers, P=8, 16K tokens/device, a different hotspot per layer\n",
        model.name,
        model.num_moe_layers()
    );

    let ep = engine.run_model(&lms, &PlannerKind::StandardEp).expect("ep");
    let ll = engine.run_model(&lms, &PlannerKind::llep_default()).expect("llep");

    let mut t = Table::new(&[
        "planner", "model latency", "serial", "overlap saved", "peak mem", "fallback layers",
    ]);
    for r in [&ep, &ll] {
        t.row(vec![
            r.planner.clone(),
            format_secs(r.latency_s),
            format_secs(r.serial_latency_s),
            format_secs(r.overlap_saved_s),
            format_bytes(r.max_peak_bytes()),
            format!("{}/{}", r.fallback_layers, r.num_layers()),
        ]);
    }
    println!("{}", t.render());
    println!(
        "multi-layer LLEP speedup: {:.2}x  (peak memory {:.2}x lower)\n",
        ep.latency_s / ll.latency_s,
        ep.max_peak_bytes() as f64 / ll.max_peak_bytes().max(1) as f64
    );
    assert!(
        ll.latency_s < ep.latency_s,
        "LLEP must win under depth-varying imbalance"
    );

    // Per-layer breakdown: hotspots move across layers, plans follow.
    println!("LLEP per-layer breakdown (first 8 layers):");
    let mut table = model_report_table(&ll);
    table.rows.truncate(8);
    println!("{}", table.render());
}
