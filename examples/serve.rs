//! Serving example: batched requests through the MoE engine under EP vs
//! LLEP on an imbalanced routing distribution, reporting per-request
//! latency percentiles and throughput — the "higher-throughput
//! inference" claim of the paper.
//!
//! Run: `cargo run --release --example serve`

use llep::coordinator::{ContinuousBatchSim, ServeSim};
use llep::metrics::{format_secs, Table};
use llep::prelude::*;

fn main() {
    let engine = Engine::modeled(
        ModelConfig::preset(ModelPreset::GptOss120b),
        SystemConfig::preset(SystemPreset::H200x8),
    );
    let mut rng = Rng::new(0);
    // 200 requests, bursty arrivals, 256-4096 tokens each.
    let requests = ServeSim::poisson_requests(200, 0.0002, 256, 4096, &mut rng);
    println!(
        "serving {} requests ({} total tokens) | gpt-oss-120b, {} MoE layers per step | 80% \
         into 4 experts\n",
        requests.len(),
        requests.iter().map(|r| r.tokens).sum::<usize>(),
        engine.model.num_moe_layers()
    );

    let mut table = Table::new(&[
        "planner", "makespan", "p50 latency", "p90 latency", "p99 latency", "tokens/s", "batches",
    ]);
    for kind in [PlannerKind::StandardEp, PlannerKind::llep_default()] {
        let sim = ServeSim::new(engine.clone(), kind, Scenario::concentrated(0.8, 4), 16_384);
        let r = sim.run(&requests, &mut Rng::new(1));
        assert_eq!(r.completed, requests.len(), "all requests must complete");
        table.row(vec![
            r.planner.clone(),
            format_secs(r.makespan_s),
            format_secs(r.request_latency.p50),
            format_secs(r.request_latency.p90),
            format_secs(r.request_latency.p99),
            format!("{:.0}", r.throughput_tps()),
            r.batches.to_string(),
        ]);
    }
    println!("{}", table.render());

    // ------------------------------------------------------------------
    // Continuous batching (vLLM-style prefill + decode interleaving).
    // ------------------------------------------------------------------
    let mut rng = Rng::new(2);
    let gen_reqs =
        ContinuousBatchSim::requests(64, 0.0003, (512, 4096), (8, 32), &mut rng);
    println!(
        "continuous batching: {} generation requests (prefill 512-4096, decode 8-32 steps)\n",
        gen_reqs.len()
    );
    let mut t2 = Table::new(&[
        "planner", "makespan", "TTFT p50", "TTFT p99", "TPOT p50", "steps", "EP-fallback steps",
    ]);
    for kind in [PlannerKind::StandardEp, PlannerKind::llep_default()] {
        let sim = ContinuousBatchSim::new(
            engine.clone(),
            kind,
            Scenario::concentrated(0.8, 4),
            16_384,
        );
        let r = sim.run(&gen_reqs, &mut Rng::new(3));
        assert_eq!(r.completed, gen_reqs.len());
        t2.row(vec![
            r.planner.clone(),
            format_secs(r.makespan_s),
            format_secs(r.ttft.p50),
            format_secs(r.ttft.p99),
            format_secs(r.tpot.p50),
            r.steps.to_string(),
            r.fallback_steps.to_string(),
        ]);
    }
    println!("{}", t2.render());
}
