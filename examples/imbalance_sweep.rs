//! Imbalance sweep: regenerate the paper's Fig. 1a/1b (speedup and peak
//! memory across imbalance scenarios) and Fig. 4 (three architectures),
//! printing the same rows the paper plots.
//!
//! Run: `cargo run --release --example imbalance_sweep`

use llep::harness;

fn main() {
    println!("== Fig 1a — MoE layer speedup (128E / top-4 / D=2048, P=8, B=32K) ==");
    println!("{}", harness::fig_1a().render());

    println!("== Fig 1b — peak memory per GPU ==");
    println!("{}", harness::fig_1b().render());

    println!("== Fig 4 — gpt-oss-120b / DeepSeek-V3 / Kimi-K2 ==");
    println!("{}", harness::fig_4().render());

    println!("== Fig 1c — full-model throughput (in-the-wild routing) ==");
    println!("{}", harness::fig_1c().render());
}
